//! Length-framed byte transport for the serve protocol.
//!
//! Wire format: a 4-byte big-endian length prefix, then exactly that
//! many payload bytes (one JSON document). A reader that hits EOF *on*
//! a frame boundary sees a clean close (`Ok(None)`); EOF *inside* a
//! frame is an error. Frames above the caller's cap are rejected
//! WITHOUT reading the body — the server answers with a typed error
//! envelope and closes only that connection (the byte stream cannot be
//! resynchronized once a declared length is ignored), leaving every
//! other client untouched (`tests/serve_proto.rs`).

use std::io::{self, Read, Write};

/// Default frame cap (1 MiB) — generous for JSON control traffic,
/// small enough that a hostile length prefix cannot balloon memory.
pub const DEFAULT_MAX_FRAME: usize = 1 << 20;

/// Why a frame could not be read.
#[derive(Debug)]
pub enum FrameError {
    /// Declared length exceeds the cap; the body was NOT consumed.
    Oversized {
        /// Length the prefix declared.
        len: u64,
        /// The cap it exceeded.
        max: usize,
    },
    /// Transport error (including EOF mid-frame).
    Io(io::Error),
}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> Self {
        FrameError::Io(e)
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { len, max } => {
                write!(f, "frame of {len} bytes exceeds the {max}-byte cap")
            }
            FrameError::Io(e) => write!(f, "frame transport error: {e}"),
        }
    }
}

/// Read one frame. `Ok(None)` means the peer closed cleanly between
/// frames. Handles arbitrarily split reads (the header loop below and
/// `read_exact` for the body both tolerate partial reads).
pub fn read_frame(r: &mut impl Read, max: usize) -> Result<Option<Vec<u8>>, FrameError> {
    let mut header = [0u8; 4];
    let mut filled = 0;
    while filled < header.len() {
        match r.read(&mut header[filled..]) {
            Ok(0) if filled == 0 => return Ok(None),
            Ok(0) => {
                return Err(FrameError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed inside a frame header",
                )))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > max {
        return Err(FrameError::Oversized {
            len: len as u64,
            max,
        });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

/// Write one frame (header + body) and flush.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    let len = u32::try_from(body.len())
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "frame body over 4 GiB"))?;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A reader that hands out at most one byte per `read` call —
    /// the worst possible TCP segmentation.
    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
    }

    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.pos == self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    fn framed(body: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, body).unwrap();
        out
    }

    #[test]
    fn roundtrip_through_one_byte_reads() {
        let mut wire = framed(b"{\"type\":\"stats\"}");
        wire.extend_from_slice(&framed(b""));
        let mut r = Trickle {
            data: &wire,
            pos: 0,
        };
        assert_eq!(
            read_frame(&mut r, DEFAULT_MAX_FRAME).unwrap().unwrap(),
            b"{\"type\":\"stats\"}"
        );
        assert_eq!(read_frame(&mut r, DEFAULT_MAX_FRAME).unwrap().unwrap(), b"");
        assert!(
            read_frame(&mut r, DEFAULT_MAX_FRAME).unwrap().is_none(),
            "EOF on the frame boundary is a clean close"
        );
    }

    #[test]
    fn eof_inside_header_or_body_is_an_error() {
        for cut in [1, 2, 3, 5] {
            let wire = framed(b"abcd");
            let mut r = Trickle {
                data: &wire[..cut],
                pos: 0,
            };
            assert!(
                matches!(read_frame(&mut r, DEFAULT_MAX_FRAME), Err(FrameError::Io(_))),
                "truncation at byte {cut} must surface as an I/O error"
            );
        }
    }

    #[test]
    fn oversized_frame_is_rejected_without_reading_the_body() {
        let mut wire = 9_000_000u32.to_be_bytes().to_vec();
        wire.extend_from_slice(b"body that never gets read");
        let mut r = Trickle {
            data: &wire,
            pos: 0,
        };
        match read_frame(&mut r, 4096) {
            Err(FrameError::Oversized { len, max }) => {
                assert_eq!(len, 9_000_000);
                assert_eq!(max, 4096);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
        assert_eq!(r.pos, 4, "only the header was consumed");
    }

    #[test]
    fn zero_length_frame_roundtrips() {
        let wire = framed(b"");
        assert_eq!(wire, [0, 0, 0, 0]);
        let mut r = Trickle {
            data: &wire,
            pos: 0,
        };
        assert_eq!(read_frame(&mut r, 16).unwrap().unwrap(), Vec::<u8>::new());
    }
}
