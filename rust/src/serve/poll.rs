//! A minimal `poll(2)` wrapper over raw fds — the readiness primitive
//! under the pooled serve engine.
//!
//! The crate carries no `libc` dependency (nothing may be added
//! offline), so the one syscall the engine needs is declared by hand:
//! `struct pollfd` is three C ints/shorts with a stable layout on
//! every Linux/BSD libc, and `poll` itself has had the same signature
//! since POSIX.1-2001. Only the four event bits the engine uses are
//! exposed.

use std::io;
use std::os::unix::io::RawFd;

/// Readable (or a peer close pending — a subsequent read returns 0).
pub const POLLIN: i16 = 0x001;
/// Writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (revents only).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (revents only).
pub const POLLHUP: i16 = 0x010;

/// One entry of the poll set — layout-compatible with C's
/// `struct pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// The fd to watch (negative entries are ignored by the kernel).
    pub fd: RawFd,
    /// Requested events (`POLLIN` / `POLLOUT`).
    pub events: i16,
    /// Returned events (kernel-filled; includes `POLLERR`/`POLLHUP`).
    pub revents: i16,
}

impl PollFd {
    /// Watch `fd` for `events`, with `revents` cleared.
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd {
            fd,
            events,
            revents: 0,
        }
    }

    /// Any of `bits` reported back by the kernel?
    pub fn has(&self, bits: i16) -> bool {
        self.revents & bits != 0
    }
}

extern "C" {
    fn poll(fds: *mut PollFd, nfds: std::ffi::c_ulong, timeout: std::ffi::c_int) -> std::ffi::c_int;
}

/// Block until at least one fd in `fds` is ready or `timeout_ms`
/// elapses (`-1` = forever). Returns the number of ready entries
/// (0 on timeout); `EINTR` is retried internally.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as std::ffi::c_ulong, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn reports_readability_and_timeouts() {
        let (mut a, b) = UnixStream::pair().unwrap();
        let mut set = [PollFd::new(b.as_raw_fd(), POLLIN)];
        // nothing written yet: a short poll times out with 0 ready
        assert_eq!(poll_fds(&mut set, 10).unwrap(), 0);
        assert!(!set[0].has(POLLIN));
        a.write_all(b"x").unwrap();
        a.flush().unwrap();
        assert_eq!(poll_fds(&mut set, 1000).unwrap(), 1);
        assert!(set[0].has(POLLIN));
    }

    #[test]
    fn reports_writability_immediately() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut set = [PollFd::new(a.as_raw_fd(), POLLOUT)];
        assert_eq!(poll_fds(&mut set, 1000).unwrap(), 1);
        assert!(set[0].has(POLLOUT));
    }
}
