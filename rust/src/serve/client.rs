//! A typed synchronous client for the serve protocol — what
//! `ace serve-probe`, the integration tests, and the federation link
//! (`serve::federate`) drive.
//!
//! One TCP connection, blocking request/response with client-side
//! correlation ids, behind a typed surface: [`Client::connect`]
//! returns a [`Connect`] builder, every op returns a domain value or a
//! [`ServeError`], and protocol failures carry the server's stable
//! error slug as an [`ErrorCode`] instead of a stringly-typed prefix.
//! Asynchronous `message` pushes can arrive BETWEEN a request and its
//! response; the client parks them in a queue that
//! [`Client::recv_message`] drains.

use super::b64;
use super::frame::{read_frame, write_frame, FrameError, DEFAULT_MAX_FRAME};
use crate::json::{self, Value};
use std::collections::VecDeque;
use std::fmt;
use std::io;
use std::net::TcpStream;
use std::time::Duration;

/// The server's stable machine-readable error slugs, typed. Unknown
/// slugs (a newer server) land in [`ErrorCode::Other`] instead of
/// failing to parse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ErrorCode {
    BadUtf8,
    BadJson,
    BadEnvelope,
    BadType,
    MissingField,
    BadPayload,
    BadScenario,
    ScenarioFailed,
    OversizedFrame,
    InvalidTopic,
    InvalidFilter,
    UnsupportedVersion,
    Other(String),
}

impl ErrorCode {
    /// The typed code for a wire slug.
    pub fn from_slug(s: &str) -> ErrorCode {
        match s {
            "bad-utf8" => ErrorCode::BadUtf8,
            "bad-json" => ErrorCode::BadJson,
            "bad-envelope" => ErrorCode::BadEnvelope,
            "bad-type" => ErrorCode::BadType,
            "missing-field" => ErrorCode::MissingField,
            "bad-payload" => ErrorCode::BadPayload,
            "bad-scenario" => ErrorCode::BadScenario,
            "scenario-failed" => ErrorCode::ScenarioFailed,
            "oversized-frame" => ErrorCode::OversizedFrame,
            "invalid-topic" => ErrorCode::InvalidTopic,
            "invalid-filter" => ErrorCode::InvalidFilter,
            "unsupported-version" => ErrorCode::UnsupportedVersion,
            other => ErrorCode::Other(other.to_string()),
        }
    }

    /// The wire slug for this code.
    pub fn slug(&self) -> &str {
        match self {
            ErrorCode::BadUtf8 => "bad-utf8",
            ErrorCode::BadJson => "bad-json",
            ErrorCode::BadEnvelope => "bad-envelope",
            ErrorCode::BadType => "bad-type",
            ErrorCode::MissingField => "missing-field",
            ErrorCode::BadPayload => "bad-payload",
            ErrorCode::BadScenario => "bad-scenario",
            ErrorCode::ScenarioFailed => "scenario-failed",
            ErrorCode::OversizedFrame => "oversized-frame",
            ErrorCode::InvalidTopic => "invalid-topic",
            ErrorCode::InvalidFilter => "invalid-filter",
            ErrorCode::UnsupportedVersion => "unsupported-version",
            ErrorCode::Other(s) => s,
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.slug())
    }
}

/// Everything that can go wrong talking to a serve server.
#[derive(Debug)]
pub enum ServeError {
    /// Socket-level failure (connect, read, write).
    Io(io::Error),
    /// The server closed the connection at a frame boundary.
    Closed,
    /// The server answered with a typed `error` envelope.
    Protocol { code: ErrorCode, message: String },
    /// The server answered with something this client cannot make
    /// sense of (malformed envelope, mismatched correlation id).
    Unexpected(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Io(e) => write!(f, "transport error: {e}"),
            ServeError::Closed => f.write_str("server closed the connection"),
            // the legacy "code: message" shape, now typed
            ServeError::Protocol { code, message } => write!(f, "{code}: {message}"),
            ServeError::Unexpected(s) => f.write_str(s),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for ServeError {
    fn from(e: io::Error) -> Self {
        ServeError::Io(e)
    }
}

impl ServeError {
    /// Is this a protocol error with the given code?
    pub fn is_code(&self, code: &ErrorCode) -> bool {
        matches!(self, ServeError::Protocol { code: c, .. } if c == code)
    }
}

/// The `stats_ok` reply, typed: broker identity, protocol version,
/// capability slugs, and the counter snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub broker: String,
    pub shards: usize,
    /// Protocol version the server speaks (0 on a pre-`v` server).
    pub v: u64,
    /// Capability slugs ([`super::proto::CAPABILITIES`]); empty on a
    /// pre-capability server.
    pub capabilities: Vec<String>,
    pub pub_count: u64,
    pub pub_bytes: u64,
    pub deliver_count: u64,
    pub deliver_bytes: u64,
    pub subscriptions: u64,
}

impl Stats {
    /// Does the server advertise `cap`?
    pub fn has_capability(&self, cap: &str) -> bool {
        self.capabilities.iter().any(|c| c == cap)
    }

    fn from_value(v: &Value) -> Result<Stats, ServeError> {
        let st = v.get("stats");
        let count = |field: &str| -> Result<u64, ServeError> {
            st.get(field)
                .as_f64()
                .map(|f| f as u64)
                .ok_or_else(|| ServeError::Unexpected(format!("malformed stats_ok: {v}")))
        };
        Ok(Stats {
            broker: v.get("broker").as_str().unwrap_or("").to_string(),
            shards: v.get("shards").as_usize().unwrap_or(0),
            v: v.get("v").as_f64().unwrap_or(0.0) as u64,
            capabilities: v
                .get("capabilities")
                .as_arr()
                .map(|a| a.iter().filter_map(|c| c.as_str().map(str::to_string)).collect())
                .unwrap_or_default(),
            pub_count: count("pubCount")?,
            pub_bytes: count("pubBytes")?,
            deliver_count: count("deliverCount")?,
            deliver_bytes: count("deliverBytes")?,
            subscriptions: count("subscriptions")?,
        })
    }
}

/// A typed non-push response envelope.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    PublishOk { reached: usize },
    SubscribeOk { id: u64 },
    UnsubscribeOk { removed: bool },
    StatsOk(Stats),
    ScenarioOk { app: String, report: Value },
    ShutdownOk,
}

impl Response {
    /// Parse a response envelope; `error` envelopes become
    /// [`ServeError::Protocol`].
    pub fn parse(v: Value) -> Result<Response, ServeError> {
        let malformed = |v: &Value, what: &str| {
            ServeError::Unexpected(format!("malformed {what}: {v}"))
        };
        match v.get("type").as_str() {
            Some("publish_ok") => v
                .get("reached")
                .as_usize()
                .map(|reached| Response::PublishOk { reached })
                .ok_or_else(|| malformed(&v, "publish_ok")),
            Some("subscribe_ok") => v
                .get("subscriptionId")
                .as_f64()
                .map(|f| Response::SubscribeOk { id: f as u64 })
                .ok_or_else(|| malformed(&v, "subscribe_ok")),
            Some("unsubscribe_ok") => v
                .get("removed")
                .as_bool()
                .map(|removed| Response::UnsubscribeOk { removed })
                .ok_or_else(|| malformed(&v, "unsubscribe_ok")),
            Some("stats_ok") => Stats::from_value(&v).map(Response::StatsOk),
            Some("scenario_ok") => match v.get("app").as_str() {
                Some(app) => Ok(Response::ScenarioOk {
                    app: app.to_string(),
                    report: v.get("report").clone(),
                }),
                None => Err(malformed(&v, "scenario_ok")),
            },
            Some("shutdown_ok") => Ok(Response::ShutdownOk),
            Some("error") => Err(ServeError::Protocol {
                code: ErrorCode::from_slug(v.get("code").as_str().unwrap_or("?")),
                message: v.get("message").as_str().unwrap_or("?").to_string(),
            }),
            Some(other) => Err(ServeError::Unexpected(format!(
                "unknown response type '{other}': {v}"
            ))),
            None => Err(ServeError::Unexpected(format!("untyped envelope: {v}"))),
        }
    }
}

/// A delivery received over the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct Delivery {
    pub subscription_id: u64,
    pub topic: String,
    pub payload: Vec<u8>,
    /// Broker the message FIRST entered (federation loop suppression).
    pub origin: String,
    /// Retain-as-published: a retained replay, or a live publish that
    /// asked to retain (what a federation link re-retains on its peer).
    pub retained: bool,
}

impl Delivery {
    fn from_value(v: &Value) -> Result<Delivery, ServeError> {
        Ok(Delivery {
            subscription_id: v.get("subscriptionId").as_f64().unwrap_or(0.0) as u64,
            topic: v.get("topic").as_str().unwrap_or("").to_string(),
            payload: b64::decode(v.get("payload").as_str().unwrap_or("")).map_err(|e| {
                ServeError::Unexpected(format!("malformed message payload: {e}"))
            })?,
            origin: v.get("origin").as_str().unwrap_or("").to_string(),
            retained: v.get("retained").as_bool().unwrap_or(false),
        })
    }
}

/// Connection builder: `Client::connect(addr).retries(..).open()`.
#[derive(Debug, Clone)]
pub struct Connect {
    addr: String,
    attempts: u32,
    delay: Duration,
    max_frame: usize,
}

impl Connect {
    /// Retry the TCP connect `attempts` times, `delay` apart — lets a
    /// probe start before the server finishes binding (the CI smoke
    /// starts both concurrently). Default: one attempt.
    pub fn retries(mut self, attempts: u32, delay: Duration) -> Connect {
        self.attempts = attempts.max(1);
        self.delay = delay;
        self
    }

    /// Frame-size cap for INBOUND frames (default
    /// [`DEFAULT_MAX_FRAME`]).
    pub fn max_frame(mut self, max: usize) -> Connect {
        self.max_frame = max;
        self
    }

    /// Open the connection.
    pub fn open(self) -> Result<Client, ServeError> {
        let mut last: Option<io::Error> = None;
        for attempt in 0..self.attempts {
            match TcpStream::connect(&self.addr) {
                Ok(stream) => {
                    return Ok(Client {
                        stream,
                        parked: VecDeque::new(),
                        next_req: 1,
                        max_frame: self.max_frame,
                    })
                }
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < self.attempts {
                        std::thread::sleep(self.delay);
                    }
                }
            }
        }
        Err(ServeError::Io(last.unwrap_or_else(|| {
            io::Error::other("no connection attempts made")
        })))
    }
}

/// One client connection.
pub struct Client {
    stream: TcpStream,
    /// `message` pushes that arrived while waiting for a response.
    parked: VecDeque<Value>,
    next_req: u64,
    max_frame: usize,
}

impl Client {
    /// Start building a connection to `addr` (e.g. `127.0.0.1:7878`).
    pub fn connect(addr: &str) -> Connect {
        Connect {
            addr: addr.to_string(),
            attempts: 1,
            delay: Duration::from_millis(250),
            max_frame: DEFAULT_MAX_FRAME,
        }
    }

    /// A raw clone of the underlying stream — the federation link's
    /// writer half sends fire-and-forget publish envelopes on it while
    /// the reader half keeps draining this client.
    pub fn try_clone_stream(&self) -> io::Result<TcpStream> {
        self.stream.try_clone()
    }

    /// Send raw bytes as one frame — protocol-robustness tests use
    /// this to inject malformed payloads.
    pub fn send_raw(&mut self, body: &[u8]) -> io::Result<()> {
        write_frame(&mut self.stream, body)
    }

    /// Read the next frame of any kind (responses AND pushes).
    fn read_envelope(&mut self) -> Result<Value, ServeError> {
        match read_frame(&mut self.stream, self.max_frame) {
            Ok(Some(bytes)) => {
                let text = String::from_utf8(bytes)
                    .map_err(|e| ServeError::Unexpected(format!("non-UTF-8 frame: {e}")))?;
                json::parse(&text)
                    .map_err(|e| ServeError::Unexpected(format!("non-JSON frame: {e}")))
            }
            Ok(None) => Err(ServeError::Closed),
            Err(FrameError::Oversized { len, max }) => Err(ServeError::Unexpected(format!(
                "server sent a {len}-byte frame (cap {max})"
            ))),
            Err(FrameError::Io(e)) => Err(ServeError::Io(e)),
        }
    }

    /// Read frames until a non-`message` envelope arrives, parking any
    /// pushes; `error` envelopes become [`ServeError::Protocol`].
    pub fn read_response(&mut self) -> Result<Response, ServeError> {
        loop {
            let v = self.read_envelope()?;
            if v.get("type").as_str() == Some("message") {
                self.parked.push_back(v);
                continue;
            }
            return Response::parse(v);
        }
    }

    /// One request/response exchange; verifies the echoed requestId.
    fn rpc(&mut self, mut fields: Vec<(&str, Value)>) -> Result<Response, ServeError> {
        let rid = format!("r{}", self.next_req);
        self.next_req += 1;
        fields.push(("requestId", Value::str(rid.as_str())));
        let body = json::to_string(&Value::obj(fields));
        self.send_raw(body.as_bytes())?;
        loop {
            let v = self.read_envelope()?;
            if v.get("type").as_str() == Some("message") {
                self.parked.push_back(v);
                continue;
            }
            // an error that never parsed far enough to echo the id
            // still belongs to this in-flight request (the protocol is
            // strictly one response per request, in order)
            match v.get("requestId").as_str() {
                Some(got) if got == rid => {}
                None if v.get("type").as_str() == Some("error") => {}
                other => {
                    return Err(ServeError::Unexpected(format!(
                        "requestId mismatch: sent {rid:?}, got {other:?}"
                    )))
                }
            }
            return Response::parse(v);
        }
    }

    /// Publish; returns the number of subscribers reached.
    pub fn publish(
        &mut self,
        topic: &str,
        payload: &[u8],
        retain: bool,
    ) -> Result<usize, ServeError> {
        self.publish_fields(topic, payload, retain, None)
    }

    /// Publish with a pre-stamped origin (federation passthrough — the
    /// message keeps the broker name it FIRST entered).
    pub fn publish_from(
        &mut self,
        topic: &str,
        payload: &[u8],
        retain: bool,
        origin: &str,
    ) -> Result<usize, ServeError> {
        self.publish_fields(topic, payload, retain, Some(origin))
    }

    fn publish_fields(
        &mut self,
        topic: &str,
        payload: &[u8],
        retain: bool,
        origin: Option<&str>,
    ) -> Result<usize, ServeError> {
        let mut fields = vec![
            ("type", Value::str("publish")),
            ("topic", Value::str(topic)),
            ("payload", Value::str(b64::encode(payload))),
            ("retain", Value::Bool(retain)),
        ];
        if let Some(o) = origin {
            fields.push(("origin", Value::str(o)));
        }
        match self.rpc(fields)? {
            Response::PublishOk { reached } => Ok(reached),
            other => Err(ServeError::Unexpected(format!(
                "expected publish_ok, got {other:?}"
            ))),
        }
    }

    /// Subscribe; returns the server-assigned subscription id.
    pub fn subscribe(&mut self, filter: &str) -> Result<u64, ServeError> {
        match self.rpc(vec![
            ("type", Value::str("subscribe")),
            ("filter", Value::str(filter)),
        ])? {
            Response::SubscribeOk { id } => Ok(id),
            other => Err(ServeError::Unexpected(format!(
                "expected subscribe_ok, got {other:?}"
            ))),
        }
    }

    /// Unsubscribe; `Ok(false)` means the id was unknown (or owned by
    /// another connection).
    pub fn unsubscribe(&mut self, id: u64) -> Result<bool, ServeError> {
        match self.rpc(vec![
            ("type", Value::str("unsubscribe")),
            ("subscriptionId", Value::num(id as f64)),
        ])? {
            Response::UnsubscribeOk { removed } => Ok(removed),
            other => Err(ServeError::Unexpected(format!(
                "expected unsubscribe_ok, got {other:?}"
            ))),
        }
    }

    /// The broker's identity, capabilities, and counter snapshot.
    pub fn stats(&mut self) -> Result<Stats, ServeError> {
        match self.rpc(vec![("type", Value::str("stats"))])? {
            Response::StatsOk(st) => Ok(st),
            other => Err(ServeError::Unexpected(format!(
                "expected stats_ok, got {other:?}"
            ))),
        }
    }

    /// Run a yamlite scenario document on the server
    /// (`svcgraph::scenario`); returns the dispatched app and its
    /// summary report. Blocks until the run completes.
    pub fn scenario(&mut self, doc: &str) -> Result<(String, Value), ServeError> {
        match self.rpc(vec![
            ("type", Value::str("scenario")),
            ("scenario", Value::str(b64::encode(doc.as_bytes()))),
        ])? {
            Response::ScenarioOk { app, report } => Ok((app, report)),
            other => Err(ServeError::Unexpected(format!(
                "expected scenario_ok, got {other:?}"
            ))),
        }
    }

    /// Ask the server to stop accepting and exit its serve loop.
    pub fn shutdown(&mut self) -> Result<(), ServeError> {
        match self.rpc(vec![("type", Value::str("shutdown"))])? {
            Response::ShutdownOk => Ok(()),
            other => Err(ServeError::Unexpected(format!(
                "expected shutdown_ok, got {other:?}"
            ))),
        }
    }

    /// Next envelope of ANY kind within `timeout` — parked pushes
    /// first, then the socket. `Ok(None)` on timeout. The federation
    /// link reads with this (its writer half publishes concurrently,
    /// so responses and pushes interleave on the read side).
    pub fn next_envelope(&mut self, timeout: Duration) -> Result<Option<Value>, ServeError> {
        if let Some(v) = self.parked.pop_front() {
            return Ok(Some(v));
        }
        self.stream.set_read_timeout(Some(timeout))?;
        let got = read_frame(&mut self.stream, self.max_frame);
        self.stream.set_read_timeout(None)?;
        match got {
            Ok(Some(bytes)) => {
                let text = String::from_utf8(bytes)
                    .map_err(|e| ServeError::Unexpected(format!("non-UTF-8 frame: {e}")))?;
                json::parse(&text)
                    .map(Some)
                    .map_err(|e| ServeError::Unexpected(format!("non-JSON frame: {e}")))
            }
            Ok(None) => Err(ServeError::Closed),
            // a timeout with NO bytes read is a clean "nothing yet"; a
            // timeout mid-frame would surface as UnexpectedEof or a
            // later desync, which callers never trigger (the server
            // writes frames atomically before the deadline)
            Err(FrameError::Io(e))
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                Ok(None)
            }
            Err(FrameError::Io(e)) => Err(ServeError::Io(e)),
            Err(FrameError::Oversized { len, max }) => Err(ServeError::Unexpected(format!(
                "server sent a {len}-byte frame (cap {max})"
            ))),
        }
    }

    /// Next delivery: a parked push if one is queued, otherwise block
    /// on the socket up to `timeout`. `Ok(None)` on timeout.
    pub fn recv_message(&mut self, timeout: Duration) -> Result<Option<Delivery>, ServeError> {
        match self.next_envelope(timeout)? {
            None => Ok(None),
            Some(v) if v.get("type").as_str() == Some("message") => {
                Delivery::from_value(&v).map(Some)
            }
            Some(v) => Err(ServeError::Unexpected(format!(
                "expected a message push, got: {v}"
            ))),
        }
    }

    /// Let tests observe the unsolicited-push backlog.
    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }
}
