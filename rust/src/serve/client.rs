//! A small synchronous client for the serve protocol — the in-repo
//! test client the CI smoke job drives (`ace serve-probe`) and the
//! integration tests reuse.
//!
//! One TCP connection, blocking request/response with client-side
//! correlation ids. Asynchronous `message` pushes can arrive BETWEEN a
//! request and its response; the client parks them in a queue that
//! [`Client::recv_message`] drains.

use super::b64;
use super::frame::{read_frame, write_frame, FrameError, DEFAULT_MAX_FRAME};
use crate::json::{self, Value};
use std::collections::VecDeque;
use std::io;
use std::net::TcpStream;
use std::time::Duration;

/// One client connection.
pub struct Client {
    stream: TcpStream,
    /// `message` pushes that arrived while waiting for a response.
    parked: VecDeque<Value>,
    next_req: u64,
}

/// A delivery received over the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct Delivery {
    pub subscription_id: u64,
    pub topic: String,
    pub payload: Vec<u8>,
    pub origin: String,
}

impl Client {
    /// Connect once.
    pub fn connect(addr: &str) -> io::Result<Client> {
        Ok(Client {
            stream: TcpStream::connect(addr)?,
            parked: VecDeque::new(),
            next_req: 1,
        })
    }

    /// Connect with retries — lets a probe start before the server
    /// finishes binding (the CI smoke starts both concurrently).
    pub fn connect_retry(addr: &str, attempts: u32, delay: Duration) -> io::Result<Client> {
        let mut last = None;
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(delay);
                }
            }
        }
        Err(last.unwrap_or_else(|| io::Error::other("no connection attempts made")))
    }

    /// Send raw bytes as one frame — protocol-robustness tests use
    /// this to inject malformed payloads.
    pub fn send_raw(&mut self, body: &[u8]) -> io::Result<()> {
        write_frame(&mut self.stream, body)
    }

    /// Read the next frame of any kind (responses AND pushes).
    fn read_envelope(&mut self) -> Result<Value, String> {
        match read_frame(&mut self.stream, DEFAULT_MAX_FRAME) {
            Ok(Some(bytes)) => {
                let text = String::from_utf8(bytes).map_err(|e| e.to_string())?;
                json::parse(&text).map_err(|e| e.to_string())
            }
            Ok(None) => Err("server closed the connection".into()),
            Err(FrameError::Oversized { len, max }) => {
                Err(format!("server sent a {len}-byte frame (cap {max})"))
            }
            Err(FrameError::Io(e)) => Err(format!("transport error: {e}")),
        }
    }

    /// Read frames until a non-`message` envelope arrives, parking any
    /// pushes; error envelopes become `Err("code: message")`.
    pub fn read_response(&mut self) -> Result<Value, String> {
        loop {
            let v = self.read_envelope()?;
            match v.get("type").as_str() {
                Some("message") => self.parked.push_back(v),
                Some("error") => {
                    return Err(format!(
                        "{}: {}",
                        v.get("code").as_str().unwrap_or("?"),
                        v.get("message").as_str().unwrap_or("?")
                    ))
                }
                Some(_) => return Ok(v),
                None => return Err(format!("untyped envelope: {v}")),
            }
        }
    }

    /// One request/response exchange; verifies the echoed requestId.
    fn rpc(&mut self, mut fields: Vec<(&str, Value)>) -> Result<Value, String> {
        let rid = format!("r{}", self.next_req);
        self.next_req += 1;
        fields.push(("requestId", Value::str(rid.as_str())));
        let body = json::to_string(&Value::obj(fields));
        self.send_raw(body.as_bytes())
            .map_err(|e| format!("send failed: {e}"))?;
        let resp = self.read_response()?;
        match resp.get("requestId").as_str() {
            Some(got) if got == rid => Ok(resp),
            other => Err(format!("requestId mismatch: sent {rid:?}, got {other:?}")),
        }
    }

    /// Publish; returns the number of subscribers reached.
    pub fn publish(&mut self, topic: &str, payload: &[u8], retain: bool) -> Result<usize, String> {
        let resp = self.rpc(vec![
            ("type", Value::str("publish")),
            ("topic", Value::str(topic)),
            ("payload", Value::str(b64::encode(payload))),
            ("retain", Value::Bool(retain)),
        ])?;
        resp.get("reached")
            .as_usize()
            .ok_or_else(|| format!("malformed publish_ok: {resp}"))
    }

    /// Subscribe; returns the server-assigned subscription id.
    pub fn subscribe(&mut self, filter: &str) -> Result<u64, String> {
        let resp = self.rpc(vec![
            ("type", Value::str("subscribe")),
            ("filter", Value::str(filter)),
        ])?;
        resp.get("subscriptionId")
            .as_f64()
            .map(|f| f as u64)
            .ok_or_else(|| format!("malformed subscribe_ok: {resp}"))
    }

    /// Unsubscribe; `Ok(false)` means the id was unknown (or owned by
    /// another connection).
    pub fn unsubscribe(&mut self, id: u64) -> Result<bool, String> {
        let resp = self.rpc(vec![
            ("type", Value::str("unsubscribe")),
            ("subscriptionId", Value::num(id as f64)),
        ])?;
        resp.get("removed")
            .as_bool()
            .ok_or_else(|| format!("malformed unsubscribe_ok: {resp}"))
    }

    /// The broker's counter snapshot (the raw `stats_ok` envelope).
    pub fn stats(&mut self) -> Result<Value, String> {
        self.rpc(vec![("type", Value::str("stats"))])
    }

    /// Ask the server to stop accepting and exit its accept loop.
    pub fn shutdown(&mut self) -> Result<(), String> {
        self.rpc(vec![("type", Value::str("shutdown"))]).map(|_| ())
    }

    /// Next delivery: a parked push if one is queued, otherwise block
    /// on the socket up to `timeout`. `Ok(None)` on timeout.
    pub fn recv_message(&mut self, timeout: Duration) -> Result<Option<Delivery>, String> {
        let v = if let Some(v) = self.parked.pop_front() {
            v
        } else {
            self.stream
                .set_read_timeout(Some(timeout))
                .map_err(|e| e.to_string())?;
            let got = read_frame(&mut self.stream, DEFAULT_MAX_FRAME);
            self.stream
                .set_read_timeout(None)
                .map_err(|e| e.to_string())?;
            match got {
                Ok(Some(bytes)) => {
                    let text = String::from_utf8(bytes).map_err(|e| e.to_string())?;
                    json::parse(&text).map_err(|e| e.to_string())?
                }
                Ok(None) => return Err("server closed the connection".into()),
                // a timeout with NO bytes read is a clean "nothing yet";
                // a timeout mid-frame would surface as UnexpectedEof or
                // a later desync, which tests never trigger (the server
                // writes frames atomically before the deadline)
                Err(FrameError::Io(e))
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(None)
                }
                Err(e) => return Err(e.to_string()),
            }
        };
        if v.get("type").as_str() != Some("message") {
            return Err(format!("expected a message push, got: {v}"));
        }
        Ok(Some(Delivery {
            subscription_id: v.get("subscriptionId").as_f64().unwrap_or(0.0) as u64,
            topic: v.get("topic").as_str().unwrap_or("").to_string(),
            payload: b64::decode(v.get("payload").as_str().unwrap_or(""))
                .map_err(|e| format!("malformed message payload: {e}"))?,
            origin: v.get("origin").as_str().unwrap_or("").to_string(),
        }))
    }

    /// Let tests observe the unsolicited-push backlog.
    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }
}
