//! TCP federation: join two `ace serve` processes into one logical
//! topic space over the serve protocol itself.
//!
//! A [`Link`] is a protocol CLIENT of a peer server, owned by the
//! local one. It does two things:
//!
//! * PULL — subscribe the configured filters on the peer; every
//!   delivery push that comes back is republished into the local
//!   broker with its `origin` preserved, and with `retain` set when
//!   the push carried the retain-as-published flag (so the peer's
//!   retained state is re-retained locally, including the replay burst
//!   that fires right at subscribe time).
//! * PUSH — register `Broker::subscribe_sink` closures on the local
//!   broker for the configured filters; matching local messages are
//!   sent to the peer as `publish` envelopes carrying their `origin`
//!   and retain flag. Registration replays local retained state, so
//!   the peer inherits it too.
//!
//! # Loop suppression
//!
//! Two rules make any federation graph loop-free:
//!
//! * a message is only ever PUSHED by the broker it first entered
//!   (`origin == local name`) — a republished copy is never pushed
//!   onward;
//! * the pull side never republishes a message whose `origin` is the
//!   local broker — a copy that came home is dropped.
//!
//! Every copy of a message therefore moves strictly away from its
//! origin broker (one push hop, any number of pull hops), and no
//! broker republishes the same origin-stamped message it already owns.
//! Multi-path pull topologies can still deliver duplicates (as in MQTT
//! bridging); the two-process pairing `ace serve --federate` sets up
//! cannot.
//!
//! The link thread reconnects with backoff until the peer appears,
//! re-running the subscribe handshake each time; outbound sinks write
//! straight from the publisher's thread (TCP buffering absorbs bursts;
//! a slow peer back-pressures local publishers rather than dropping).

use super::b64;
use super::client::Client;
use super::frame::write_frame;
use crate::json::{self, Value};
use crate::pubsub::{Broker, Message};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// Federation settings (`ace serve --federate <addr>`).
#[derive(Debug, Clone)]
pub struct FederateConfig {
    /// Peer server address (`host:port` of the other `ace serve`).
    pub peer: String,
    /// Filters to PULL from the peer into the local broker.
    pub pull: Vec<String>,
    /// Filters whose local matches are PUSHED to the peer.
    pub push: Vec<String>,
}

impl FederateConfig {
    /// Federate everything, both directions.
    pub fn all(peer: impl Into<String>) -> FederateConfig {
        FederateConfig {
            peer: peer.into(),
            pull: vec!["#".into()],
            push: vec!["#".into()],
        }
    }
}

/// Forwarding counters, snapshot via [`Link::stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LinkStats {
    /// Messages republished locally from peer pushes.
    pub inbound: u64,
    /// Local messages forwarded to the peer.
    pub outbound: u64,
    /// Sessions re-established after the first.
    pub reconnects: u64,
}

#[derive(Default)]
struct Counters {
    inbound: AtomicU64,
    outbound: AtomicU64,
    reconnects: AtomicU64,
}

/// A running federation link (owned by `Server::run`, or directly by
/// the federation tests).
pub struct Link {
    own_stop: Arc<AtomicBool>,
    counters: Arc<Counters>,
    thread: JoinHandle<()>,
}

impl Link {
    /// Start the link thread: connect (and keep reconnecting) to
    /// `cfg.peer`, bridging against `local`. The link also winds down
    /// when `server_stop` flips — the owning server's shutdown.
    pub fn start(cfg: FederateConfig, local: Broker, server_stop: Arc<AtomicBool>) -> Link {
        let own_stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let thread = {
            let own_stop = own_stop.clone();
            let counters = counters.clone();
            thread::Builder::new()
                .name("serve-federate".into())
                .spawn(move || run_link(cfg, local, server_stop, own_stop, counters))
                .expect("spawn federation link thread")
        };
        Link {
            own_stop,
            counters,
            thread,
        }
    }

    /// Forwarding counters so far.
    pub fn stats(&self) -> LinkStats {
        LinkStats {
            inbound: self.counters.inbound.load(Ordering::Relaxed),
            outbound: self.counters.outbound.load(Ordering::Relaxed),
            reconnects: self.counters.reconnects.load(Ordering::Relaxed),
        }
    }

    /// Stop the link and join its thread (returns within the link's
    /// 250 ms read tick).
    pub fn shutdown(self) {
        self.own_stop.store(true, Ordering::SeqCst);
        let _ = self.thread.join();
    }
}

fn stopped(server_stop: &AtomicBool, own_stop: &AtomicBool) -> bool {
    server_stop.load(Ordering::SeqCst) || own_stop.load(Ordering::SeqCst)
}

fn run_link(
    cfg: FederateConfig,
    local: Broker,
    server_stop: Arc<AtomicBool>,
    own_stop: Arc<AtomicBool>,
    counters: Arc<Counters>,
) {
    let mut sessions = 0u64;
    while !stopped(&server_stop, &own_stop) {
        sessions += 1;
        if sessions > 1 {
            counters.reconnects.fetch_add(1, Ordering::Relaxed);
        }
        if link_session(&cfg, &local, &server_stop, &own_stop, &counters).is_ok() {
            return; // clean stop
        }
        // peer gone (or not up yet): back off, stop-aware
        for _ in 0..5 {
            if stopped(&server_stop, &own_stop) {
                return;
            }
            thread::sleep(Duration::from_millis(100));
        }
    }
}

/// One connected session: handshake, subscribe the pulls, register the
/// push sinks, then pump inbound deliveries until the link stops
/// (`Ok`) or the connection dies (`Err` — the caller reconnects).
fn link_session(
    cfg: &FederateConfig,
    local: &Broker,
    server_stop: &AtomicBool,
    own_stop: &AtomicBool,
    counters: &Arc<Counters>,
) -> Result<(), String> {
    let mut c = Client::connect(&cfg.peer)
        .open()
        .map_err(|e| format!("federation connect to {}: {e}", cfg.peer))?;
    let peer = c.stats().map_err(|e| format!("federation handshake: {e}"))?;
    if !peer.has_capability("origin-publish") {
        // without origin passthrough the peer would re-stamp every
        // forwarded message as its own and loop suppression breaks
        return Err(format!(
            "peer '{}' does not advertise the origin-publish capability",
            peer.broker
        ));
    }
    for f in &cfg.pull {
        c.subscribe(f).map_err(|e| format!("federation pull subscribe '{f}': {e}"))?;
    }

    // outbound half: local matches with a LOCAL origin go to the peer
    // as fire-and-forget publish envelopes on a clone of the stream
    // (their publish_ok responses are discarded by the pump below)
    let writer: Arc<Mutex<TcpStream>> = Arc::new(Mutex::new(
        c.try_clone_stream().map_err(|e| format!("federation stream clone: {e}"))?,
    ));
    let alive = Arc::new(AtomicBool::new(true));
    let local_name = local.name();
    let mut push_ids = Vec::with_capacity(cfg.push.len());
    for f in &cfg.push {
        let writer = writer.clone();
        let alive = alive.clone();
        let origin_mine = local_name.clone();
        let counters = counters.clone();
        let id = local
            .subscribe_sink(f, move |_id, m, retained| {
                if !alive.load(Ordering::SeqCst) {
                    return false; // session over: let the broker prune us
                }
                if m.origin != origin_mine {
                    // only the origin broker pushes a message onward
                    return true;
                }
                let body = json::to_string(&publish_envelope(m, retained)).into_bytes();
                let mut w = writer.lock().unwrap();
                if write_frame(&mut *w, &body).is_err() {
                    alive.store(false, Ordering::SeqCst);
                    return false;
                }
                counters.outbound.fetch_add(1, Ordering::Relaxed);
                true
            })
            .map_err(|e| format!("federation push subscribe '{f}': {e}"))?;
        push_ids.push(id);
    }

    // inbound pump: republish peer deliveries, drop everything else
    // (publish_ok chatter from the outbound half)
    let result = loop {
        if stopped(server_stop, own_stop) {
            break Ok(());
        }
        if !alive.load(Ordering::SeqCst) {
            break Err("federation outbound write failed".to_string());
        }
        match c.next_envelope(Duration::from_millis(250)) {
            Ok(None) => continue,
            Ok(Some(v)) => {
                if v.get("type").as_str() != Some("message") {
                    continue;
                }
                let origin = v.get("origin").as_str().unwrap_or("");
                if origin == &*local_name {
                    continue; // our own message came home: drop it
                }
                let Some(topic) = v.get("topic").as_str() else {
                    continue;
                };
                let Ok(payload) = b64::decode(v.get("payload").as_str().unwrap_or("")) else {
                    continue;
                };
                let retained = v.get("retained").as_bool().unwrap_or(false);
                let mut msg = Message::new(topic, payload);
                if !origin.is_empty() {
                    msg.origin = Arc::from(origin);
                }
                if local.publish_opts(msg, retained).is_ok() {
                    counters.inbound.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(e) => break Err(format!("federation read: {e}")),
        }
    };

    // session teardown: stop the sinks, drop the peer subscriptions
    // with the connection itself
    alive.store(false, Ordering::SeqCst);
    for id in push_ids {
        local.unsubscribe(id);
    }
    result
}

/// A `publish` envelope that preserves the message's origin stamp and
/// retain-as-published flag across the hop.
fn publish_envelope(m: &Message, retained: bool) -> Value {
    Value::obj(vec![
        ("type", Value::str("publish")),
        ("topic", Value::str(m.topic.as_str())),
        ("payload", Value::str(b64::encode(&m.payload))),
        ("retain", Value::Bool(retained)),
        ("origin", Value::str(&*m.origin)),
    ])
}
