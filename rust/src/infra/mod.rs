//! Infrastructure organization (§4.3.1).
//!
//! ACE organizes each user's nodes as several Edge Clouds (ECs) plus
//! one Central Cloud (CC). Ids are hierarchical (three layers):
//! `infra-X / {ec-N | cc} / node`. Each EC/CC is a cluster with its own
//! broker (resource-level message service instance) so ECs stay
//! autonomous under WAN partition (Principle Two); node agents
//! subscribe to their deploy topic and report status.

pub mod agent;

use crate::util::AceId;
use std::collections::BTreeMap;

/// Hardware class of a node — mirrors the paper's testbed (§5.1.1) and
/// sets the DES speed factor (service time multiplier relative to the
/// calibration host).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Camera-attached edge node (paper: Raspberry Pi).
    RaspberryPi,
    /// EC aggregation node (paper: X86 mini PC).
    MiniPc,
    /// CC node (paper: GPU workstation).
    GpuWorkstation,
    /// Generic cloud server.
    CloudServer,
}

impl NodeKind {
    /// DES service-time multiplier vs the calibration host. Chosen so
    /// the EOC-on-edge vs COC-on-CC asymmetry matches §5.2's measured
    /// 44 ms vs 32.3 ms shape (see DESIGN.md §Substitutions).
    pub fn speed_factor(self) -> f64 {
        match self {
            NodeKind::RaspberryPi => 6.0,
            NodeKind::MiniPc => 2.0,
            NodeKind::GpuWorkstation => 1.0,
            NodeKind::CloudServer => 1.0,
        }
    }

    pub fn default_resources(self) -> Resources {
        match self {
            NodeKind::RaspberryPi => Resources { cpu_millis: 4000, mem_mb: 4096 },
            NodeKind::MiniPc => Resources { cpu_millis: 8000, mem_mb: 16384 },
            NodeKind::GpuWorkstation => Resources { cpu_millis: 32000, mem_mb: 65536 },
            NodeKind::CloudServer => Resources { cpu_millis: 16000, mem_mb: 32768 },
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            NodeKind::RaspberryPi => "rpi",
            NodeKind::MiniPc => "minipc",
            NodeKind::GpuWorkstation => "gpu-ws",
            NodeKind::CloudServer => "cloud",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    pub cpu_millis: u32,
    pub mem_mb: u32,
}

impl Resources {
    pub fn fits(&self, req: &Resources) -> bool {
        self.cpu_millis >= req.cpu_millis && self.mem_mb >= req.mem_mb
    }

    pub fn sub(&mut self, req: &Resources) {
        self.cpu_millis -= req.cpu_millis;
        self.mem_mb -= req.mem_mb;
    }

    pub fn add(&mut self, req: &Resources) {
        self.cpu_millis += req.cpu_millis;
        self.mem_mb += req.mem_mb;
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Ready,
    /// Shielded by the controller after missed heartbeats (§4.2.1
    /// "shields failed nodes").
    Failed,
    /// Administratively removed from scheduling.
    Cordoned,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: AceId,
    pub kind: NodeKind,
    pub capacity: Resources,
    pub allocatable: Resources,
    pub labels: BTreeMap<String, String>,
    pub status: NodeStatus,
}

impl Node {
    pub fn schedulable(&self) -> bool {
        self.status == NodeStatus::Ready
    }

    pub fn has_label(&self, key: &str, value: Option<&str>) -> bool {
        match (self.labels.get(key), value) {
            (Some(v), Some(want)) => v == want,
            (Some(_), None) => true,
            (None, _) => false,
        }
    }

    pub fn is_edge(&self) -> bool {
        matches!(self.kind, NodeKind::RaspberryPi | NodeKind::MiniPc)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterKind {
    EdgeCloud,
    CentralCloud,
}

/// One EC or the CC: a named cluster of nodes (§4.3.1 "internal nodes
/// are organized as a cluster").
#[derive(Debug, Clone)]
pub struct Cluster {
    pub id: AceId,
    pub kind: ClusterKind,
    pub nodes: Vec<Node>,
}

impl Cluster {
    pub fn node(&self, leaf: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.id.leaf() == leaf)
    }

    pub fn node_mut(&mut self, leaf: &str) -> Option<&mut Node> {
        self.nodes.iter_mut().find(|n| n.id.leaf() == leaf)
    }
}

/// A registered user's full ECC infrastructure.
#[derive(Debug, Clone)]
pub struct Infrastructure {
    pub id: AceId,
    pub ecs: Vec<Cluster>,
    pub cc: Cluster,
}

impl Infrastructure {
    /// All clusters, CC last.
    pub fn clusters(&self) -> impl Iterator<Item = &Cluster> {
        self.ecs.iter().chain(std::iter::once(&self.cc))
    }

    pub fn cluster(&self, leaf: &str) -> Option<&Cluster> {
        self.clusters().find(|c| c.id.leaf() == leaf)
    }

    pub fn cluster_mut(&mut self, leaf: &str) -> Option<&mut Cluster> {
        if self.cc.id.leaf() == leaf {
            return Some(&mut self.cc);
        }
        self.ecs.iter_mut().find(|c| c.id.leaf() == leaf)
    }

    pub fn all_nodes(&self) -> impl Iterator<Item = (&Cluster, &Node)> {
        self.clusters().flat_map(|c| c.nodes.iter().map(move |n| (c, n)))
    }

    pub fn find_node(&self, id: &AceId) -> Option<&Node> {
        self.all_nodes().map(|(_, n)| n).find(|n| &n.id == id)
    }

    pub fn find_node_mut(&mut self, id: &AceId) -> Option<&mut Node> {
        let cluster_leaf = id.parent()?.leaf().to_string();
        self.cluster_mut(&cluster_leaf)?.node_mut(id.leaf())
    }
}

/// Builder reproducing the registration protocol of §4.3.1: ACE assigns
/// the infrastructure id, then per-EC/CC ids, then per-node ids as
/// agents check in.
pub struct InfraBuilder {
    id: AceId,
    ecs: Vec<Cluster>,
    cc_nodes: Vec<Node>,
    next_ec: usize,
}

impl InfraBuilder {
    pub fn register(user: &str) -> Self {
        InfraBuilder {
            id: AceId::root(format!("infra-{user}")),
            ecs: Vec::new(),
            cc_nodes: Vec::new(),
            next_ec: 1,
        }
    }

    /// Claim a new EC; returns its id for node registration.
    pub fn claim_ec(&mut self) -> AceId {
        let id = self.id.child(format!("ec-{}", self.next_ec));
        self.next_ec += 1;
        self.ecs.push(Cluster { id: id.clone(), kind: ClusterKind::EdgeCloud, nodes: Vec::new() });
        id
    }

    /// Register a node into the EC with id `ec` (agent check-in).
    pub fn add_edge_node(
        &mut self,
        ec: &AceId,
        name: &str,
        kind: NodeKind,
        labels: BTreeMap<String, String>,
    ) -> AceId {
        let cluster = self
            .ecs
            .iter_mut()
            .find(|c| &c.id == ec)
            .expect("unknown EC id");
        let id = ec.child(name);
        let caps = kind.default_resources();
        cluster.nodes.push(Node {
            id: id.clone(),
            kind,
            capacity: caps,
            allocatable: caps,
            labels,
            status: NodeStatus::Ready,
        });
        id
    }

    pub fn add_cloud_node(
        &mut self,
        name: &str,
        kind: NodeKind,
        labels: BTreeMap<String, String>,
    ) -> AceId {
        let id = self.id.child("cc").child(name);
        let caps = kind.default_resources();
        self.cc_nodes.push(Node {
            id: id.clone(),
            kind,
            capacity: caps,
            allocatable: caps,
            labels,
            status: NodeStatus::Ready,
        });
        id
    }

    pub fn build(self) -> Infrastructure {
        Infrastructure {
            cc: Cluster {
                id: self.id.child("cc"),
                kind: ClusterKind::CentralCloud,
                nodes: self.cc_nodes,
            },
            id: self.id,
            ecs: self.ecs,
        }
    }
}

/// The paper's §5.1.1 testbed: 3 ECs x (1 mini PC + 3 RPis w/ cameras)
/// + 1 CC GPU workstation.
pub fn paper_testbed(user: &str) -> Infrastructure {
    let mut b = InfraBuilder::register(user);
    for _ in 0..3 {
        let ec = b.claim_ec();
        b.add_edge_node(&ec, "minipc", NodeKind::MiniPc, BTreeMap::new());
        for r in 1..=3 {
            let mut labels = BTreeMap::new();
            labels.insert("camera".to_string(), "true".to_string());
            b.add_edge_node(&ec, &format!("rpi{r}"), NodeKind::RaspberryPi, labels);
        }
    }
    b.add_cloud_node("gpu-ws", NodeKind::GpuWorkstation, BTreeMap::new());
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let infra = paper_testbed("u1");
        assert_eq!(infra.ecs.len(), 3);
        assert_eq!(infra.cc.nodes.len(), 1);
        for ec in &infra.ecs {
            assert_eq!(ec.nodes.len(), 4);
            assert_eq!(
                ec.nodes.iter().filter(|n| n.has_label("camera", None)).count(),
                3
            );
        }
        assert_eq!(infra.all_nodes().count(), 13);
    }

    #[test]
    fn three_layer_ids() {
        let infra = paper_testbed("u1");
        let (_, node) = infra.all_nodes().next().unwrap();
        assert_eq!(node.id.depth(), 3);
        assert!(infra.id.is_ancestor_of(&node.id));
        let found = infra.find_node(&node.id).unwrap();
        assert_eq!(found.id, node.id);
    }

    #[test]
    fn resources_arithmetic() {
        let mut r = Resources { cpu_millis: 1000, mem_mb: 512 };
        let req = Resources { cpu_millis: 300, mem_mb: 128 };
        assert!(r.fits(&req));
        r.sub(&req);
        assert_eq!(r.cpu_millis, 700);
        r.add(&req);
        assert_eq!(r.mem_mb, 512);
        assert!(!Resources { cpu_millis: 100, mem_mb: 512 }.fits(&req));
    }

    #[test]
    fn find_node_mut_updates_status() {
        let mut infra = paper_testbed("u1");
        let id = infra.ecs[0].nodes[1].id.clone();
        infra.find_node_mut(&id).unwrap().status = NodeStatus::Failed;
        assert_eq!(infra.find_node(&id).unwrap().status, NodeStatus::Failed);
        assert!(!infra.find_node(&id).unwrap().schedulable());
    }

    #[test]
    fn speed_factors_preserve_paper_asymmetry() {
        // EOC on mini PC vs COC on GPU WS: edge must be slower than
        // cloud per crop, like the paper's 44 ms vs 32.3 ms.
        assert!(NodeKind::MiniPc.speed_factor() > NodeKind::GpuWorkstation.speed_factor());
        assert!(NodeKind::RaspberryPi.speed_factor() > NodeKind::MiniPc.speed_factor());
    }
}
