//! Node agent (§4.3.1): deployed on every node at registration; it
//! receives deployment instructions from the platform controller over
//! the message service, manages "containers" (in-process component
//! records), and reports node + component status for monitoring.
//!
//! Topics:
//!   * `ace/deploy/<node-id>`   — controller -> agent: compose-YAML
//!     instruction (deploy/remove component instances);
//!   * `ace/status/<node-id>`   — agent -> monitoring: heartbeat +
//!     running instance list (JSON).

use crate::json::{self, Value};
use crate::pubsub::{Broker, Message};
use crate::util::AceId;
use crate::yamlite;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A "container" the agent runs (instance of an application component).
#[derive(Debug, Clone, PartialEq)]
pub struct Running {
    pub instance: String,
    pub component: String,
    pub app: String,
    pub image: String,
}

pub struct Agent {
    pub node: AceId,
    running: Arc<Mutex<Vec<Running>>>,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    broker: Broker,
}

impl Agent {
    /// Start the agent: subscribe to this node's deploy topic and apply
    /// instructions as they arrive.
    pub fn start(node: AceId, broker: Broker) -> Result<Agent, String> {
        let topic = format!("ace/deploy/{}", node.to_string().replace('/', "."));
        let sub = broker.subscribe(&topic)?;
        let running: Arc<Mutex<Vec<Running>>> = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let r2 = running.clone();
        let s2 = stop.clone();
        let b2 = broker.clone();
        let node2 = node.clone();
        let thread = std::thread::spawn(move || {
            while !s2.load(Ordering::Relaxed) {
                match sub.rx.recv_timeout(std::time::Duration::from_millis(20)) {
                    Ok(msg) => {
                        Self::apply(&node2, &r2, &msg);
                        Self::report(&node2, &r2, &b2);
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        });
        Ok(Agent { node, running, stop, thread: Some(thread), broker })
    }

    /// Apply a compose-style instruction (yamlite document with a
    /// `services` mapping; absent services are removed — the agent
    /// converges to the instruction, like docker-compose up).
    fn apply(node: &AceId, running: &Arc<Mutex<Vec<Running>>>, msg: &Message) {
        let doc = match yamlite::parse(&msg.utf8()) {
            Ok(d) => d,
            Err(_) => return, // malformed instruction: ignored, status unchanged
        };
        let services = doc.get("services");
        let mut new_running = Vec::new();
        if let Some(obj) = services.as_obj() {
            for (name, svc) in obj {
                new_running.push(Running {
                    instance: name.clone(),
                    component: svc
                        .get("labels")
                        .get("ace.component")
                        .as_str()
                        .unwrap_or(name)
                        .to_string(),
                    app: svc
                        .get("labels")
                        .get("ace.app")
                        .as_str()
                        .unwrap_or("")
                        .to_string(),
                    image: svc.get("image").as_str().unwrap_or("").to_string(),
                });
            }
        }
        let _ = node;
        *running.lock().unwrap() = new_running;
    }

    fn report(node: &AceId, running: &Arc<Mutex<Vec<Running>>>, broker: &Broker) {
        let list = running.lock().unwrap();
        let instances: Vec<Value> = list
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("instance", Value::str(&r.instance)),
                    ("component", Value::str(&r.component)),
                    ("app", Value::str(&r.app)),
                    ("state", Value::str("running")),
                ])
            })
            .collect();
        let status = Value::obj(vec![
            ("node", Value::str(node.to_string())),
            ("instances", Value::Arr(instances)),
        ]);
        let topic = format!("ace/status/{}", node.to_string().replace('/', "."));
        let _ = broker.publish(&topic, json::to_string(&status).into_bytes());
    }

    /// Force an immediate status report (heartbeat).
    pub fn heartbeat(&self) {
        Self::report(&self.node, &self.running, &self.broker);
    }

    pub fn running(&self) -> Vec<Running> {
        self.running.lock().unwrap().clone()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Agent {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Render the deploy-topic name for a node (shared with the controller).
pub fn deploy_topic(node: &AceId) -> String {
    format!("ace/deploy/{}", node.to_string().replace('/', "."))
}

/// Render the status-topic name for a node.
pub fn status_topic(node: &AceId) -> String {
    format!("ace/status/{}", node.to_string().replace('/', "."))
}

/// Render the instruction-ack topic name for a node (the virtual
/// control plane's at-least-once channel).
pub fn ack_topic(node: &AceId) -> String {
    format!("ace/ack/{}", node.to_string().replace('/', "."))
}

/// Build a compose-style instruction document for a node.
pub fn compose_instruction(
    app: &str,
    services: &[(String, String, String)], // (instance, component, image)
) -> String {
    compose_doc(app, services, None)
}

/// [`compose_instruction`] plus a top-level monotonic `seq` stamp —
/// the at-least-once channel's dedupe key. Backward-compatible wire
/// format: both the threaded [`Agent`] and the simulated node agent
/// read only `services`, so a stamped document converges identically.
pub fn compose_instruction_seq(
    app: &str,
    services: &[(String, String, String)],
    seq: u64,
) -> String {
    compose_doc(app, services, Some(seq))
}

fn compose_doc(app: &str, services: &[(String, String, String)], seq: Option<u64>) -> String {
    let mut svc_map = BTreeMap::new();
    for (instance, component, image) in services {
        let labels = Value::obj(vec![
            ("ace.app", Value::str(app)),
            ("ace.component", Value::str(component)),
        ]);
        svc_map.insert(
            instance.clone(),
            Value::obj(vec![
                ("image", Value::str(image)),
                ("labels", labels),
                ("restart", Value::str("unless-stopped")),
            ]),
        );
    }
    let mut pairs = vec![
        ("version", Value::str("3.8")),
        ("services", Value::Obj(svc_map)),
    ];
    if let Some(seq) = seq {
        pairs.push(("seq", Value::num(seq as f64)));
    }
    yamlite::to_string(&Value::obj(pairs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn wait_for<F: Fn() -> bool>(f: F) {
        for _ in 0..200 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("condition not reached");
    }

    #[test]
    fn agent_applies_instruction_and_reports() {
        let broker = Broker::new("ec-1");
        let node = AceId::parse("infra-1/ec-1/rpi1");
        let status_sub = broker.subscribe(&status_topic(&node)).unwrap();
        let agent = Agent::start(node.clone(), broker.clone()).unwrap();

        let doc = compose_instruction(
            "videoquery",
            &[("od-1".into(), "od".into(), "ace/od:1".into())],
        );
        broker.publish(&deploy_topic(&node), doc.into_bytes()).unwrap();

        wait_for(|| agent.running().len() == 1);
        let r = &agent.running()[0];
        assert_eq!(r.component, "od");
        assert_eq!(r.app, "videoquery");
        assert_eq!(r.image, "ace/od:1");

        let status = status_sub.rx.recv_timeout(Duration::from_secs(2)).unwrap();
        let v = crate::json::parse(&status.utf8()).unwrap();
        assert_eq!(v.get("instances").idx(0).get("state").as_str(), Some("running"));
    }

    #[test]
    fn agent_converges_to_new_instruction() {
        let broker = Broker::new("ec-1");
        let node = AceId::parse("infra-1/ec-1/rpi2");
        let agent = Agent::start(node.clone(), broker.clone()).unwrap();
        let d1 = compose_instruction(
            "vq",
            &[
                ("od-1".into(), "od".into(), "i1".into()),
                ("eoc-1".into(), "eoc".into(), "i2".into()),
            ],
        );
        broker.publish(&deploy_topic(&node), d1.into_bytes()).unwrap();
        wait_for(|| agent.running().len() == 2);
        // update: only one service remains -> the other is removed
        let d2 = compose_instruction("vq", &[("od-1".into(), "od".into(), "i1b".into())]);
        broker.publish(&deploy_topic(&node), d2.into_bytes()).unwrap();
        wait_for(|| {
            let r = agent.running();
            r.len() == 1 && r[0].image == "i1b"
        });
    }

    #[test]
    fn seq_stamp_is_backward_compatible_wire_format() {
        let services = vec![("od-1".to_string(), "od".to_string(), "i1".to_string())];
        let stamped = compose_instruction_seq("vq", &services, 42);
        let v = yamlite::parse(&stamped).unwrap();
        assert_eq!(v.get("seq").as_f64(), Some(42.0));
        assert_eq!(
            v.get("services"),
            yamlite::parse(&compose_instruction("vq", &services))
                .unwrap()
                .get("services"),
            "the stamp must not perturb the services mapping"
        );
        // and the threaded agent (which ignores unknown top-level keys)
        // converges on a stamped document exactly as on a plain one
        let broker = Broker::new("ec-1");
        let node = AceId::parse("infra-1/ec-1/rpi9");
        let agent = Agent::start(node.clone(), broker.clone()).unwrap();
        broker.publish(&deploy_topic(&node), stamped.into_bytes()).unwrap();
        wait_for(|| agent.running().len() == 1);
        assert_eq!(agent.running()[0].image, "i1");
    }

    #[test]
    fn empty_instruction_stops_everything() {
        let broker = Broker::new("ec-1");
        let node = AceId::parse("infra-1/ec-1/rpi3");
        let agent = Agent::start(node.clone(), broker.clone()).unwrap();
        let d1 = compose_instruction("vq", &[("x".into(), "x".into(), "i".into())]);
        broker.publish(&deploy_topic(&node), d1.into_bytes()).unwrap();
        wait_for(|| agent.running().len() == 1);
        let d2 = compose_instruction("vq", &[]);
        broker.publish(&deploy_topic(&node), d2.into_bytes()).unwrap();
        wait_for(|| agent.running().is_empty());
    }
}
