//! Evaluation metrics (§5.2): F1-score, bandwidth consumption (BWC),
//! end-to-end inference latency (EIL), and table emitters.
//!
//! F1 follows the paper's footnote 1: real-time streams are unlabelled,
//! so ALL crops extracted by OD are classified by COC after the run and
//! COC's predictions are the ground truth. Footnote 2: EIL is the time
//! from a crop being transmitted by OD until its predicted label is
//! produced by EOC or COC.

use crate::util::stats::Percentiles;

/// Binary confusion counts + F1.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct F1 {
    pub tp: u64,
    pub fp: u64,
    pub fn_: u64,
    pub tn: u64,
}

impl F1 {
    pub fn add(&mut self, predicted: bool, actual: bool) {
        match (predicted, actual) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, true) => self.fn_ += 1,
            (false, false) => self.tn += 1,
        }
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 1.0; // no positive predictions: vacuous precision
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 1.0; // no actual positives in the stream
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    pub fn total(&self) -> u64 {
        self.tp + self.fp + self.fn_ + self.tn
    }
}

/// One cell of Figure 5: a (paradigm, load, delay) run's metrics.
#[derive(Debug, Clone)]
pub struct CellMetrics {
    pub paradigm: String,
    /// OD sampling interval in seconds (lower = higher system load)
    pub interval_s: f64,
    pub wan_delay_ms: f64,
    pub f1: F1,
    pub eil: Percentiles,
    /// WAN bytes (up + down)
    pub bwc_bytes: u64,
    pub crops: u64,
    /// crops decided at the edge (EOC positives + drops)
    pub edge_decided: u64,
    /// crops classified by COC
    pub cloud_decided: u64,
    pub sim_duration_s: f64,
    /// Per-NIC traffic/occupancy (empty when the run models no NICs —
    /// the degenerate flat configuration). Surfaced by `ace svcrun`.
    pub nic_util: Vec<crate::simnet::LinkUtil>,
}

impl CellMetrics {
    /// BWC in MB (the Figure 5 middle-row unit).
    pub fn bwc_mb(&self) -> f64 {
        self.bwc_bytes as f64 / 1e6
    }

    /// Mean EIL in ms (Figure 5 bottom row).
    pub fn eil_ms(&self) -> f64 {
        self.eil.mean() * 1e3
    }

    pub fn eil_p99_ms(&self) -> f64 {
        self.eil.quantile(0.99) * 1e3
    }

    /// Sort the EIL sample buffer once, so every later quantile read
    /// (tables, CSV, hashes) is an O(1) index through `&self`.
    /// `run_cell` calls this before returning.
    pub fn finalize(&mut self) {
        self.eil.sort_samples();
    }
}

/// Render Figure-5-style markdown tables (one per metric x delay).
/// Cells are read-only: quantile buffers are sorted once upfront by
/// [`CellMetrics::finalize`], not re-sorted per emitter.
pub fn figure5_tables(cells: &[CellMetrics]) -> String {
    let mut out = String::new();
    let mut delays: Vec<u64> = cells.iter().map(|c| c.wan_delay_ms as u64).collect();
    delays.sort_unstable();
    delays.dedup();
    let mut intervals: Vec<String> = cells.iter().map(|c| format!("{:.2}", c.interval_s)).collect();
    intervals.sort_by(|a, b| b.partial_cmp(a).unwrap());
    intervals.dedup();
    let mut paradigms: Vec<String> = cells.iter().map(|c| c.paradigm.clone()).collect();
    paradigms.sort();
    paradigms.dedup();
    // keep the paper's order
    let order = ["CI", "EI", "ACE", "ACE+"];
    paradigms.sort_by_key(|p| order.iter().position(|o| o == p).unwrap_or(99));

    for delay in &delays {
        for (metric, label) in [
            ("f1", "F1-score"),
            ("bwc", "BWC (MB)"),
            ("eil", "mean EIL (ms)"),
        ] {
            out.push_str(&format!(
                "\n### {label} — WAN one-way delay {delay} ms\n\n| interval (s) |"
            ));
            for p in &paradigms {
                out.push_str(&format!(" {p} |"));
            }
            out.push_str("\n|---|");
            for _ in &paradigms {
                out.push_str("---|");
            }
            out.push('\n');
            for iv in &intervals {
                out.push_str(&format!("| {iv} |"));
                for p in &paradigms {
                    let cell = cells.iter().find(|c| {
                        c.paradigm == *p
                            && format!("{:.2}", c.interval_s) == *iv
                            && c.wan_delay_ms as u64 == *delay
                    });
                    match cell {
                        Some(c) => {
                            let v = match metric {
                                "f1" => format!("{:.3}", c.f1.f1()),
                                "bwc" => format!("{:.2}", c.bwc_mb()),
                                _ => format!("{:.1}", c.eil_ms()),
                            };
                            out.push_str(&format!(" {v} |"));
                        }
                        None => out.push_str(" - |"),
                    }
                }
                out.push('\n');
            }
        }
    }
    out
}

/// CSV dump (one row per cell) for external plotting.
pub fn figure5_csv(cells: &[CellMetrics]) -> String {
    let mut out = String::from(
        "paradigm,interval_s,wan_delay_ms,f1,precision,recall,bwc_mb,eil_mean_ms,eil_p50_ms,eil_p99_ms,crops,edge_decided,cloud_decided\n",
    );
    for c in cells.iter() {
        out.push_str(&format!(
            "{},{},{},{:.4},{:.4},{:.4},{:.3},{:.2},{:.2},{:.2},{},{},{}\n",
            c.paradigm,
            c.interval_s,
            c.wan_delay_ms,
            c.f1.f1(),
            c.f1.precision(),
            c.f1.recall(),
            c.bwc_mb(),
            c.eil.mean() * 1e3,
            c.eil.quantile(0.5) * 1e3,
            c.eil.quantile(0.99) * 1e3,
            c.crops,
            c.edge_decided,
            c.cloud_decided,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_known_values() {
        let mut f = F1::default();
        // 8 TP, 2 FP, 4 FN, 6 TN
        for _ in 0..8 {
            f.add(true, true);
        }
        for _ in 0..2 {
            f.add(true, false);
        }
        for _ in 0..4 {
            f.add(false, true);
        }
        for _ in 0..6 {
            f.add(false, false);
        }
        assert!((f.precision() - 0.8).abs() < 1e-12);
        assert!((f.recall() - 8.0 / 12.0).abs() < 1e-12);
        let want = 2.0 * 0.8 * (8.0 / 12.0) / (0.8 + 8.0 / 12.0);
        assert!((f.f1() - want).abs() < 1e-12);
        assert_eq!(f.total(), 20);
    }

    #[test]
    fn perfect_predictor_is_one() {
        let mut f = F1::default();
        for _ in 0..5 {
            f.add(true, true);
            f.add(false, false);
        }
        assert_eq!(f.f1(), 1.0);
    }

    #[test]
    fn degenerate_cases() {
        // never predicts positive, but positives exist -> recall 0, f1 0
        let mut f = F1::default();
        f.add(false, true);
        assert_eq!(f.f1(), 0.0);
        // empty stream -> f1 defined as 1 (vacuous)
        let g = F1::default();
        assert_eq!(g.f1(), 1.0);
    }

    fn cell(p: &str, iv: f64, d: f64) -> CellMetrics {
        let mut eil = Percentiles::new();
        eil.add(0.04);
        eil.add(0.06);
        let mut f1 = F1::default();
        f1.add(true, true);
        CellMetrics {
            paradigm: p.into(),
            interval_s: iv,
            wan_delay_ms: d,
            f1,
            eil,
            bwc_bytes: 2_000_000,
            crops: 1,
            edge_decided: 0,
            cloud_decided: 1,
            sim_duration_s: 30.0,
            nic_util: Vec::new(),
        }
    }

    #[test]
    fn tables_have_all_paradigms() {
        let cells = vec![
            cell("CI", 0.5, 0.0),
            cell("EI", 0.5, 0.0),
            cell("ACE", 0.5, 0.0),
            cell("ACE+", 0.5, 0.0),
        ];
        let t = figure5_tables(&cells);
        assert!(t.contains("| CI | EI | ACE | ACE+ |"), "{t}");
        assert!(t.contains("F1-score"));
        assert!(t.contains("BWC"));
        assert!(t.contains("EIL"));
        let csv = figure5_csv(&cells);
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.contains("ACE+,0.5,0"));
    }

    #[test]
    fn bwc_units() {
        let c = cell("CI", 0.5, 0.0);
        assert!((c.bwc_mb() - 2.0).abs() < 1e-12);
    }
}
