//! The pooled serve engine under load, plus federation semantics:
//!
//! * SOAK — 64 concurrent clients (each with a live subscription and a
//!   full fan-out exchange) must NOT grow the process thread count
//!   beyond the fixed worker pool: the readiness loop owns every
//!   socket, so connections are state, not threads. The retired
//!   thread-per-connection + thread-per-subscription engine would sit
//!   at 128+ threads in this test.
//! * FEDERATION differential — a federated pair must deliver the same
//!   per-subscriber sequence a single broker delivers for the same
//!   publish sequence, hand retained state across the link, and never
//!   echo a message back (loop suppression).
//! * SCENARIO op — a yamlite document sent by a connected client runs
//!   to completion inside the server and a bad document is a typed,
//!   recoverable error.
//!
//! The tests serialize on a file-local mutex: the soak's thread-count
//! bound and the link-handshake waits assume no sibling test is
//! spinning servers up or down concurrently.

use ace::serve::client::{Client, ErrorCode, ServeError};
use ace::serve::federate::FederateConfig;
use ace::serve::{ServeConfig, Server};
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // a poisoned lock just means a sibling test failed; run anyway
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn start(cfg: &ServeConfig) -> (String, thread::JoinHandle<std::io::Result<()>>) {
    let server = Server::bind("127.0.0.1:0", cfg).expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    (addr, thread::spawn(move || server.run()))
}

fn stop(addr: &str, handle: thread::JoinHandle<std::io::Result<()>>) {
    let mut c = Client::connect(addr).open().expect("connect for shutdown");
    c.shutdown().expect("shutdown op");
    handle.join().expect("server thread").expect("clean serve-loop exit");
}

fn threads_now() -> usize {
    std::fs::read_dir("/proc/self/task").expect("procfs task dir").count()
}

#[test]
fn worker_pool_bounds_server_threads_under_64_clients() {
    let _serial = lock();
    let pool = 4;
    let cfg = ServeConfig {
        shards: 4,
        pool,
        ..ServeConfig::default()
    };
    let baseline = threads_now();
    let (addr, handle) = start(&cfg);
    let mut clients: Vec<Client> = (0..64)
        .map(|_| Client::connect(&addr).open().expect("soak client connect"))
        .collect();
    for c in clients.iter_mut() {
        c.subscribe("soak/#").unwrap();
    }
    // everyone publishes once; each publish fans out to all 64
    for (i, c) in clients.iter_mut().enumerate() {
        let topic = format!("soak/c{i}");
        assert_eq!(c.publish(&topic, b"ping", false).unwrap(), 64);
    }
    for c in clients.iter_mut() {
        for _ in 0..64 {
            c.recv_message(Duration::from_secs(10)).unwrap().expect("soak delivery");
        }
    }
    // 64 live connections + 64 subscriptions mid-exchange: the engine
    // is still ONE poll thread + `pool` workers (+ slack for runtime
    // threads), NOT a thread per connection or per subscription
    let during = threads_now();
    assert!(
        during <= baseline + pool + 4,
        "server thread count exploded: {baseline} -> {during} with pool {pool}"
    );
    drop(clients);
    stop(&addr, handle);
}

fn collect(c: &mut Client, n: usize) -> Vec<(String, Vec<u8>)> {
    (0..n)
        .map(|i| {
            let d = c
                .recv_message(Duration::from_secs(5))
                .unwrap()
                .unwrap_or_else(|| panic!("delivery {i} missing"));
            (d.topic, d.payload)
        })
        .collect()
}

#[test]
fn federated_pair_matches_a_single_broker() {
    let _serial = lock();
    // the reference: one broker, the same publish sequence
    let (addr_ref, h_ref) = start(&ServeConfig::default());
    // the pair: b is plain; a federates with b in both directions
    let (addr_b, h_b) = start(&ServeConfig {
        broker_name: "b".into(),
        ..ServeConfig::default()
    });
    // retained state on b BEFORE the link exists: the pull side must
    // hand it off and re-retain it on a
    let mut seed = Client::connect(&addr_b).open().unwrap();
    seed.publish("cfg/x", b"v1", true).unwrap();
    let (addr_a, h_a) = start(&ServeConfig {
        broker_name: "a".into(),
        federate: Some(FederateConfig::all(addr_b.clone())),
        ..ServeConfig::default()
    });
    // the link is up once a has republished b's retained message
    let mut probe_a = Client::connect(&addr_a).open().unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while probe_a.stats().unwrap().pub_count == 0 {
        assert!(Instant::now() < deadline, "link never handed off retained state");
        thread::sleep(Duration::from_millis(25));
    }
    // ... and the handoff is RETAINED: a late a-side subscriber
    // replays it, origin intact
    let mut late_a = Client::connect(&addr_a).open().unwrap();
    late_a.subscribe("cfg/#").unwrap();
    let d = late_a
        .recv_message(Duration::from_secs(5))
        .unwrap()
        .expect("retained handoff replay");
    assert_eq!(d.topic, "cfg/x");
    assert_eq!(d.payload, b"v1");
    assert_eq!(d.origin, "b");
    assert!(d.retained, "handoff must stay retain-as-published");

    // the differential: publish through a, watch on a, b, and the
    // reference — every subscriber must see the identical sequence
    let mut sub_a = Client::connect(&addr_a).open().unwrap();
    sub_a.subscribe("diff/#").unwrap();
    let mut sub_b = Client::connect(&addr_b).open().unwrap();
    sub_b.subscribe("diff/#").unwrap();
    let mut sub_ref = Client::connect(&addr_ref).open().unwrap();
    sub_ref.subscribe("diff/#").unwrap();
    let mut pub_a = Client::connect(&addr_a).open().unwrap();
    let mut pub_ref = Client::connect(&addr_ref).open().unwrap();
    for i in 0..20 {
        let topic = format!("diff/t{i}");
        let payload = format!("m{i}");
        assert!(pub_a.publish(&topic, payload.as_bytes(), false).unwrap() >= 1);
        pub_ref.publish(&topic, payload.as_bytes(), false).unwrap();
    }
    let reference = collect(&mut sub_ref, 20);
    assert_eq!(collect(&mut sub_a, 20), reference, "a-side diverges from the single broker");
    assert_eq!(collect(&mut sub_b, 20), reference, "b-side diverges from the single broker");
    // loop suppression: no echoes trickle in afterwards on either side
    assert!(sub_a.recv_message(Duration::from_millis(300)).unwrap().is_none());
    assert!(sub_b.recv_message(Duration::from_millis(300)).unwrap().is_none());

    stop(&addr_a, h_a);
    stop(&addr_b, h_b);
    stop(&addr_ref, h_ref);
}

#[test]
fn scenario_op_runs_a_metro_document_to_completion() {
    let _serial = lock();
    let (addr, handle) = start(&ServeConfig::default());
    let mut c = Client::connect(&addr).open().unwrap();
    let (app, report) = c
        .scenario("app: metro\nduration_s: 1\necs: 1\nnodes_per_ec: 1\n")
        .expect("metro scenario over the wire");
    assert_eq!(app, "metro");
    assert!(
        report.get("frames").as_f64().unwrap_or(0.0) > 0.0,
        "scenario report carries no frames: {report}"
    );
    // a broken document is a typed error, not a dead connection
    match c.scenario("app: warp\nduration: 1\n").expect_err("bad doc must be refused") {
        ServeError::Protocol { code, .. } => assert!(
            matches!(code, ErrorCode::BadScenario | ErrorCode::ScenarioFailed),
            "unexpected error code {code}"
        ),
        other => panic!("expected a protocol error, got {other:?}"),
    }
    c.stats().expect("connection survived the bad scenario");
    stop(&addr, handle);
}
