//! Cross-layer integration + determinism goldens for the svcgraph
//! runtime: topology → orchestrator → DeploymentPlan → components →
//! bridged pub/sub transport → metrics.
//!
//! No artifacts required (synthetic compute).

use ace::app::fedtrain::{run_fedtrain, run_fedtrain_seeds, FedConfig};
use ace::app::videoquery::{
    fig5_grid, run_cell, run_sweep, CellConfig, Compute, Paradigm, ServiceTimes,
};
use ace::metrics::{figure5_csv, figure5_tables, CellMetrics};

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

/// Stable digest of everything observable in a cell's metrics.
fn metrics_hash(m: &mut CellMetrics) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv(&mut h, m.paradigm.as_bytes());
    fnv(&mut h, &m.crops.to_le_bytes());
    fnv(&mut h, &m.bwc_bytes.to_le_bytes());
    fnv(&mut h, &m.edge_decided.to_le_bytes());
    fnv(&mut h, &m.cloud_decided.to_le_bytes());
    for v in [m.f1.tp, m.f1.fp, m.f1.fn_, m.f1.tn] {
        fnv(&mut h, &v.to_le_bytes());
    }
    for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
        fnv(&mut h, &m.eil.quantile(q).to_bits().to_le_bytes());
    }
    fnv(&mut h, &m.eil.mean().to_bits().to_le_bytes());
    h
}

fn cell(p: Paradigm, seed: u64) -> CellMetrics {
    let cfg = CellConfig {
        paradigm: p,
        interval_s: 0.3,
        duration_s: 8.0,
        seed,
        ..Default::default()
    };
    run_cell(cfg, ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 })
        .unwrap()
}

#[test]
fn determinism_golden_same_seed_identical_hash() {
    // same seed + config ⇒ bit-identical CellMetrics across two full
    // stack runs (placement, transport, queues, policies, percentiles)
    for p in [Paradigm::Ci, Paradigm::AceBp, Paradigm::AceAp] {
        let h1 = metrics_hash(&mut cell(p, 7));
        let h2 = metrics_hash(&mut cell(p, 7));
        assert_eq!(h1, h2, "{p:?} not deterministic");
    }
    // and the hash is seed-sensitive (the digest actually sees data)
    let h1 = metrics_hash(&mut cell(Paradigm::AceBp, 7));
    let h3 = metrics_hash(&mut cell(Paradigm::AceBp, 8));
    assert_ne!(h1, h3, "seed must reach the metrics");
}

#[test]
fn cross_layer_videoquery_bridges_bytes_onto_wan_links() {
    // the full chain: topology parsed, orchestrator places, components
    // deployed from the plan, crops cross the EC→CC bridge, and BWC is
    // read back from the simnet WAN link counters
    let m = cell(Paradigm::AceBp, 1);
    assert!(m.crops > 10, "only {} crops", m.crops);
    assert!(
        m.bwc_bytes > 0,
        "ACE must push at least result metadata over the WAN"
    );
    // CI uploads every crop: strictly more WAN traffic than ACE
    let ci = cell(Paradigm::Ci, 1);
    assert!(ci.bwc_bytes > m.bwc_bytes);
    // every crop decided, nothing stuck in queues at exhaustion
    assert_eq!(m.edge_decided + m.cloud_decided, m.crops);
}

#[test]
fn cross_layer_fedtrain_runs_on_the_same_substrate() {
    let m = run_fedtrain(FedConfig::default()).unwrap();
    assert_eq!(m.rounds.len(), 12);
    assert!(m.wan_bytes > 0, "model traffic must cross the WAN");
    assert!(m.bridged_up > 0 && m.bridged_down > 0);
    // two runs, identical trajectory
    let m2 = run_fedtrain(FedConfig::default()).unwrap();
    assert_eq!(m.final_accuracy.to_bits(), m2.final_accuracy.to_bits());
    assert_eq!(m.wan_bytes, m2.wan_bytes);
}

#[test]
fn parallel_fig5_sweep_is_byte_identical_to_serial() {
    // the determinism regression golden for the sweep engine: the
    // parallel worker pool must produce the EXACT bytes the serial
    // loop produces — same cells, same order, same metrics — because
    // each cell is a self-contained DES world and result slots are
    // written by input index
    let grid = fig5_grid(&[0.5, 0.2], &[0.0, 50.0], 4.0, 7);
    assert_eq!(grid.len(), 16, "2 intervals x 2 delays x 4 paradigms");
    let mk = || (ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 });
    let serial = run_sweep(grid.clone(), 1, mk).unwrap();
    let parallel = run_sweep(grid, 4, mk).unwrap();
    assert_eq!(
        figure5_csv(&serial),
        figure5_csv(&parallel),
        "parallel sweep CSV must be byte-identical to the serial path"
    );
    assert_eq!(figure5_tables(&serial), figure5_tables(&parallel));
}

#[test]
fn parallel_fedtrain_seed_sweep_matches_serial() {
    let base = FedConfig { rounds: 3, ..Default::default() };
    let seeds = [1u64, 2, 3, 4];
    let parallel = run_fedtrain_seeds(&base, &seeds, 4).unwrap();
    let serial = run_fedtrain_seeds(&base, &seeds, 1).unwrap();
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
        assert_eq!(a.wan_bytes, b.wan_bytes);
        assert_eq!(a.rounds.len(), b.rounds.len());
    }
}

#[test]
fn nonstandard_shapes_run_through_the_orchestrated_path() {
    // the runtime is driven by the plan, not hard-wired to 3x3
    let cfg = CellConfig {
        paradigm: Paradigm::AceBp,
        interval_s: 0.4,
        duration_s: 6.0,
        num_ecs: 2,
        cams_per_ec: 1,
        ..Default::default()
    };
    let m = run_cell(cfg, ServiceTimes::synthetic(), Compute::Synthetic {
        target_bias: 0.05,
    })
    .unwrap();
    assert!(m.crops > 0);
    assert_eq!(m.edge_decided + m.cloud_decided, m.crops);

    let fed = run_fedtrain(FedConfig { num_ecs: 5, rounds: 3, ..Default::default() }).unwrap();
    assert_eq!(fed.rounds.len(), 3);
    assert_eq!(fed.bridged_up, 15);
}
