//! PR-5 acceptance tests for the per-node network fabric.
//!
//! 1. The DEGENERATE `NetFabric` configuration (infinite NICs, zero
//!    NIC delay, single free-backplane CC) replays the flat shared-LAN
//!    model's trajectories byte-for-byte — property-tested across
//!    paradigms, seeds, and cell shapes by running each cell twice:
//!    once with NO per-node state at all and once with an explicit
//!    unlimited NIC on EVERY node (the lookup/count paths run, the
//!    arrival times must not move).
//! 2. NIC contention is observable: starving camera-node access links
//!    produces measurably different EIL/BWC than the shared-LAN model,
//!    both through `run_cell` and through the shipped
//!    `videoquery_nic_contention.yaml` scenario (which also grows the
//!    CC into a real two-node cluster).
//!
//! No artifacts required (synthetic compute).

use ace::app::videoquery::{run_cell, run_scenario, CellConfig, Compute, Paradigm, ServiceTimes};
use ace::metrics::CellMetrics;
use ace::simnet::{NetConfig, NicSpec};
use ace::svcgraph::lifecycle::LifecycleScenario;
use ace::util::millis;

const NIC_SCENARIO: &str = include_str!("../scenarios/videoquery_nic_contention.yaml");

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

/// Stable digest of everything observable in a cell's metrics.
fn metrics_hash(m: &mut CellMetrics) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv(&mut h, m.paradigm.as_bytes());
    fnv(&mut h, &m.crops.to_le_bytes());
    fnv(&mut h, &m.bwc_bytes.to_le_bytes());
    fnv(&mut h, &m.edge_decided.to_le_bytes());
    fnv(&mut h, &m.cloud_decided.to_le_bytes());
    for v in [m.f1.tp, m.f1.fp, m.f1.fn_, m.f1.tn] {
        fnv(&mut h, &v.to_le_bytes());
    }
    for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
        fnv(&mut h, &m.eil.quantile(q).to_bits().to_le_bytes());
    }
    fnv(&mut h, &m.eil.mean().to_bits().to_le_bytes());
    h
}

fn synth() -> (ServiceTimes, Compute) {
    (ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 })
}

/// An EXPLICIT degenerate network: every node of the cell listed with
/// an unlimited (count-only) NIC — same shape knobs as the implicit
/// default, but the per-node lookup and counting paths actually run.
fn explicit_degenerate_net(cfg: &CellConfig) -> NetConfig {
    let mut nc = NetConfig {
        num_ecs: cfg.num_ecs,
        wan_delay: millis(cfg.wan_delay_ms),
        ..Default::default()
    };
    for ec in 1..=cfg.num_ecs {
        // alternate the two unlimited spellings (non-finite / <= 0)
        nc.nics.push(NicSpec {
            cluster: format!("ec-{ec}"),
            node: "minipc".into(),
            mbps: f64::INFINITY,
            delay_us: 0.0,
        });
        for r in 1..=cfg.cams_per_ec {
            nc.nics.push(NicSpec {
                cluster: format!("ec-{ec}"),
                node: format!("rpi{r}"),
                mbps: if r % 2 == 0 { 0.0 } else { f64::INFINITY },
                delay_us: 0.0,
            });
        }
    }
    nc.nics.push(NicSpec {
        cluster: "cc".into(),
        node: "gpu-ws".into(),
        mbps: f64::INFINITY,
        delay_us: 0.0,
    });
    nc
}

#[test]
fn degenerate_netfabric_replays_flat_model_trajectories() {
    // the property across paradigms x seeds x shapes: the per-node
    // fabric in its degenerate configuration must be INVISIBLE
    for paradigm in [Paradigm::Ci, Paradigm::AceBp, Paradigm::AceAp] {
        for (num_ecs, cams_per_ec) in [(3, 3), (2, 1)] {
            for seed in [1u64, 9] {
                let base = CellConfig {
                    paradigm,
                    interval_s: 0.3,
                    duration_s: 6.0,
                    num_ecs,
                    cams_per_ec,
                    seed,
                    ..Default::default()
                };
                let (svc, compute) = synth();
                let mut flat = run_cell(base.clone(), svc, compute).unwrap();
                let explicit = CellConfig { net: Some(explicit_degenerate_net(&base)), ..base };
                let (svc, compute) = synth();
                let mut listed = run_cell(explicit, svc, compute).unwrap();
                assert_eq!(
                    metrics_hash(&mut flat),
                    metrics_hash(&mut listed),
                    "{paradigm:?} {num_ecs}x{cams_per_ec} seed {seed}: \
                     explicit unlimited NICs must not move any trajectory"
                );
            }
        }
    }
}

fn starved_cfg() -> CellConfig {
    // every camera RPi in every EC gets a 2 Mbps access link; the
    // topology and placement stay put (affinity still lands eoc/lic on
    // the uncongested mini PCs), so the delta is pure transport
    let base = CellConfig {
        paradigm: Paradigm::AceBp,
        interval_s: 0.3,
        duration_s: 8.0,
        seed: 7,
        ..Default::default()
    };
    let mut nc = NetConfig { num_ecs: base.num_ecs, ..Default::default() };
    for ec in 1..=base.num_ecs {
        for r in 1..=base.cams_per_ec {
            nc.nics.push(NicSpec {
                cluster: format!("ec-{ec}"),
                node: format!("rpi{r}"),
                mbps: 2.0,
                delay_us: 200.0,
            });
        }
    }
    CellConfig { net: Some(nc), ..base }
}

#[test]
fn starved_rpi_nics_raise_eil_measurably() {
    let contended_cfg = starved_cfg();
    let flat_cfg = CellConfig { net: None, ..contended_cfg.clone() };
    let (svc, compute) = synth();
    let flat = run_cell(flat_cfg, svc, compute).unwrap();
    let (svc, compute) = synth();
    let contended = run_cell(contended_cfg, svc, compute).unwrap();
    assert_eq!(
        flat.crops, contended.crops,
        "NIC charging delays crops, it must not create or drop them"
    );
    // every OD→EOC crop hop now serializes ~12.5 ms on a 2 Mbps NIC
    // before touching the LAN: the mean EIL must rise by >= 5 ms
    assert!(
        contended.eil_ms() > flat.eil_ms() + 5.0,
        "starved NICs not visible in latency: {:.2} ms vs {:.2} ms",
        contended.eil_ms(),
        flat.eil_ms()
    );
    // the per-NIC utilization report surfaces the contention: the flat
    // run models no NICs, the starved run shows busy shaped links
    assert!(flat.nic_util.is_empty(), "no NICs configured, nothing to report");
    assert!(!contended.nic_util.is_empty());
    assert!(
        contended.nic_util.iter().any(|u| u.busy_us > 0 && u.bytes > 0),
        "starved NICs must accumulate occupancy: {:?}",
        contended.nic_util
    );
}

#[test]
fn shaped_cc_backbone_charges_bridged_traffic_both_ways() {
    // CI uploads every crop and returns every verdict over the WAN; a
    // shaped CC backbone LAN adds the gateway leg (border router ↔ CC
    // bus) to each bridged hop in BOTH directions. 2 Mbps → ~12.5 ms
    // extra serialization per ~3 kB crop, visible in every EIL sample.
    let base = CellConfig {
        paradigm: Paradigm::Ci,
        interval_s: 0.3,
        duration_s: 8.0,
        seed: 7,
        ..Default::default()
    };
    let nc = NetConfig {
        num_ecs: base.num_ecs,
        cc_lan_mbps: Some(2.0),
        cc_lan_delay: 1_000,
        ..Default::default()
    };
    let gated_cfg = CellConfig { net: Some(nc), ..base.clone() };
    let (svc, compute) = synth();
    let flat = run_cell(base, svc, compute).unwrap();
    let (svc, compute) = synth();
    let mut gated = run_cell(gated_cfg.clone(), svc, compute).unwrap();
    assert_eq!(flat.crops, gated.crops, "the gateway leg delays crops, never drops them");
    assert_eq!(flat.cloud_decided, gated.cloud_decided);
    assert!(
        gated.eil_ms() > flat.eil_ms() + 10.0,
        "gateway LAN not visible in latency: {:.2} ms vs {:.2} ms",
        gated.eil_ms(),
        flat.eil_ms()
    );
    // the CC backbone is intra-cluster: WAN byte accounting (BWC) must
    // not move when the gateway leg appears
    assert_eq!(flat.bwc_bytes, gated.bwc_bytes);
    // determinism: the gated cell replays bit-identically
    let (svc, compute) = synth();
    let mut again = run_cell(gated_cfg, svc, compute).unwrap();
    assert_eq!(metrics_hash(&mut gated), metrics_hash(&mut again));
}

#[test]
fn nic_contention_scenario_diverges_from_shared_lan_model() {
    let scenario = LifecycleScenario::parse(NIC_SCENARIO).unwrap();
    assert!(scenario.network.is_some(), "the scenario must carry a network block");
    let cfg = CellConfig {
        paradigm: Paradigm::AceBp,
        interval_s: 0.3,
        duration_s: 30.0,
        seed: 7,
        ..Default::default()
    };
    let (svc, compute) = synth();
    let contended = run_scenario(cfg.clone(), svc, compute, &scenario).unwrap();

    // the identical script with the network block stripped = the old
    // shared-LAN model
    let mut flat_scenario = scenario.clone();
    flat_scenario.network = None;
    let (svc, compute) = synth();
    let flat = run_scenario(cfg, svc, compute, &flat_scenario).unwrap();

    assert!(contended.metrics.crops > 50, "scenario produced {} crops", contended.metrics.crops);
    // the per-node fabric must be measurably different: EC-1's starved
    // camera NICs slow every crop hop out of those nodes
    assert!(
        contended.metrics.eil_ms() > flat.metrics.eil_ms() + 3.0,
        "contention not visible: {:.2} ms vs {:.2} ms",
        contended.metrics.eil_ms(),
        flat.metrics.eil_ms()
    );
    // the two-node CC is real: srv1 registered an agent, so the plane
    // saw one more node heartbeating than the flat run
    assert!(
        contended.report.status_reports > flat.report.status_reports,
        "the second CC node must heartbeat ({} vs {})",
        contended.report.status_reports,
        flat.report.status_reports
    );
    // determinism: the contended scenario replays bit-identically
    let (svc, compute) = synth();
    let again = run_scenario(
        CellConfig {
            paradigm: Paradigm::AceBp,
            interval_s: 0.3,
            duration_s: 30.0,
            seed: 7,
            ..Default::default()
        },
        svc,
        compute,
        &scenario,
    )
    .unwrap();
    assert_eq!(contended.report.hash(), again.report.hash());
    assert_eq!(contended.metrics.bwc_bytes, again.metrics.bwc_bytes);
}
