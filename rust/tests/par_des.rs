//! Acceptance for the conservative parallel DES (DESIGN.md
//! §Parallel-DES) at system scale: the serial and threaded drivers
//! must be BIT-IDENTICAL over the metro workload — every safe-window
//! digest, every final metric — across 200 randomized topologies, and
//! the application's outcome must not depend on how many partitions
//! the clusters are cut into.
//!
//! (The toy-ring driver property lives in `des::par::tests`; the
//! lifecycle goldens under laned schedulers in `tests/lifecycle.rs`.)

use ace::app::metro::{run_metro, run_metro_with, MetroConfig};
use ace::util::prng;

/// Derandomized config family: every knob drawn from the case index,
/// spanning cluster counts, shapes, loads, and WAN delays.
fn case(i: u64) -> MetroConfig {
    // range_at draws from [lo, hi)
    let r = |k: u64, lo: i64, hi: i64| prng::range_at(0xACE0 + i, k, lo, hi) as u64;
    MetroConfig {
        seed: prng::u64_at(0xACE1, i),
        ecs: r(1, 2, 7) as usize,
        nodes_per_ec: r(2, 1, 4) as usize,
        cams_per_node: r(3, 1, 3) as usize,
        duration_s: r(4, 2, 6) as f64,
        escalate_every: r(5, 2, 7),
        cam_period_ms: r(9, 20, 81) as f64,
        frame_bytes: r(6, 5_000, 40_000),
        wan_delay_ms: r(7, 5, 41) as f64,
        lan_mbps: 1_000.0,
        nic_mbps: if i % 3 == 0 { 0.0 } else { 100.0 },
        diurnal_period_s: r(8, 4, 13) as f64,
        partitions: 1,
        threads: 1,
    }
}

/// The tentpole differential: 200 random topologies, each run
/// partitioned under the serial reference driver and the threaded
/// driver, hashing after EVERY safe window. Any divergence — a
/// reordered arrival, a horizon off by one, a racy link charge —
/// shows up as the first differing `(horizon, digest)` pair.
#[test]
fn serial_vs_threaded_trajectories_are_identical_over_200_cases() {
    let mut windows_total = 0usize;
    for i in 0..200u64 {
        let mut cfg = case(i);
        cfg.partitions = 2 + (i % 3) as usize; // 2..=4, clamped to ecs inside
        let mut serial = Vec::new();
        let m1 = run_metro_with(&cfg, |h, d| serial.push((h, d)));
        assert!(!serial.is_empty(), "case {i}: no safe windows ran");
        windows_total += serial.len();

        let threaded_cfg = MetroConfig { threads: 4, ..cfg.clone() };
        let mut threaded = Vec::new();
        let m2 = run_metro_with(&threaded_cfg, |h, d| threaded.push((h, d)));

        if serial != threaded {
            let first = serial
                .iter()
                .zip(&threaded)
                .position(|(a, b)| a != b)
                .unwrap_or(serial.len().min(threaded.len()));
            panic!(
                "case {i} ({cfg:?}): trajectories diverged at window {first}: \
                 serial {:?} vs threaded {:?}",
                serial.get(first),
                threaded.get(first)
            );
        }
        assert_eq!(m1.digest, m2.digest, "case {i}: final digest diverged");
        assert_eq!(
            (m1.frames, m1.escalated, m1.replies, m1.events, m1.wan_bytes),
            (m2.frames, m2.escalated, m2.replies, m2.events, m2.wan_bytes),
            "case {i}: final metrics diverged"
        );
        assert_eq!(m1.windows, m2.windows);
    }
    // the suite actually exercised windows at scale, not degenerate
    // single-window runs
    assert!(
        windows_total > 2_000,
        "only {windows_total} windows across 200 cases — lookahead too coarse?"
    );
}

/// Cutting the same workload into 1, 2, or 4 cluster partitions must
/// not change what the application OBSERVES: frame/escalation/reply
/// counts, WAN bytes, and bridge counters are exactly equal (the free
/// CC backplane makes sharded absorb reproduce serial arrivals).
#[test]
fn partition_count_does_not_change_the_application_outcome() {
    for i in [0u64, 7, 13] {
        let cfg = MetroConfig { ecs: 4, ..case(i) };
        let base = run_metro(&cfg);
        assert!(base.replies > 0, "case {i}: no end-to-end traffic");
        assert_eq!(base.replies, base.escalated, "case {i}: run must drain");
        for parts in [2, 4] {
            let m = run_metro(&MetroConfig { partitions: parts, ..cfg.clone() });
            assert_eq!(
                (m.frames, m.escalated, m.replies, m.wan_bytes, m.bridged_up, m.bridged_down),
                (
                    base.frames,
                    base.escalated,
                    base.replies,
                    base.wan_bytes,
                    base.bridged_up,
                    base.bridged_down
                ),
                "case {i}: {parts} partitions changed the app outcome"
            );
        }
    }
}

/// Threading is pure mechanism: thread counts beyond the partition
/// count (and odd thread counts) still replay the reference.
#[test]
fn surplus_and_odd_thread_counts_replay_the_reference() {
    let cfg = MetroConfig { ecs: 3, partitions: 3, ..case(42) };
    let mut reference = Vec::new();
    run_metro_with(&cfg, |h, d| reference.push((h, d)));
    for threads in [2, 3, 8] {
        let mut got = Vec::new();
        run_metro_with(&MetroConfig { threads, ..cfg.clone() }, |h, d| got.push((h, d)));
        assert_eq!(reference, got, "{threads} threads diverged");
    }
}

/// The committed scenario files stay honest: they parse, match their
/// generator presets, and the small one runs end to end (the CI
/// scenario-smoke entry).
#[test]
fn committed_metro_scenarios_match_their_presets_and_run() {
    let small = MetroConfig::from_yaml(include_str!("../scenarios/metro_small.yaml")).unwrap();
    assert_eq!(small, MetroConfig::preset("small").unwrap());
    let mid = MetroConfig::from_yaml(include_str!("../scenarios/metro_mid.yaml")).unwrap();
    assert_eq!(mid, MetroConfig::preset("mid").unwrap());

    let m = run_metro(&MetroConfig { partitions: 4, threads: 2, ..small });
    assert!(m.frames > 0 && m.replies > 0);
    assert_eq!(m.replies, m.escalated);
}
