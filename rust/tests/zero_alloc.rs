//! Enforces the PR-3 acceptance criterion directly: the steady-state
//! publish→deliver path in `svcgraph` performs ZERO heap allocations
//! (no `Box` per event, no per-publish `Vec`, no per-publish topic
//! string) — DESIGN.md §Event-engine's allocation budget.
//!
//! This integration test is its own binary, so it can install a
//! counting global allocator without affecting any other test; it
//! contains exactly ONE test so no concurrent test pollutes the
//! counter.

use ace::benchkit;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_publish_deliver_allocates_nothing() {
    // 8 sinks over 4 EC nodes (same-node hand-offs AND LAN-charged
    // cross-node hops) plus a CC subscriber fed over the Event::Bridge
    // WAN arm, one publish per topic every 50 µs
    let (mut rt, hits) = benchkit::steady_state_runtime(8);
    // warm-up: deploy, topic interning, scratch buffers, event-heap
    // capacity all reach steady state
    rt.run_until(200_000);
    let warm_hits = hits.get();
    let warm_bridged = rt.fabric().bridged_up;
    assert!(warm_hits > 0, "warm-up must deliver");
    assert!(warm_bridged > 0, "warm-up must bridge");

    let before = ALLOCS.load(Ordering::Relaxed);
    let heap_cap = rt.event_heap_capacity();
    rt.run_until(2_000_000); // 1.8 virtual seconds of steady state
    let allocs = ALLOCS.load(Ordering::Relaxed) - before;
    let delivered = hits.get() - warm_hits;
    let bridged = rt.fabric().bridged_up - warm_bridged;

    // the event heap reached its working size during warm-up (deploy
    // pre-sizes it from the plan shape) and must never regrow
    assert_eq!(
        rt.event_heap_capacity(),
        heap_cap,
        "event heap regrew during steady state"
    );

    assert!(
        delivered > 100_000,
        "steady-state window too small to be meaningful: {delivered}"
    );
    assert!(
        bridged > 10_000,
        "the bridge arm must run inside the counted window: {bridged}"
    );
    assert_eq!(
        allocs, 0,
        "steady-state publish→deliver must not touch the allocator \
         ({delivered} deliveries + {bridged} bridge hops performed {allocs} allocations)"
    );
}
