//! The `ace serve` wire surface, pinned end to end:
//!
//! * GOLDEN round-trips for every op — exact request parses and exact
//!   response byte strings (the serializer emits sorted keys and
//!   integral numbers bare, so these are stable);
//! * TCP integration against a live server on an ephemeral port —
//!   split/partial writes reassemble, an oversized frame is answered
//!   and isolated to its own connection, malformed JSON gets a typed
//!   error without killing the connection, retained replay arrives in
//!   retain order after the subscribe ack, and the in-repo probe
//!   (what CI's smoke job runs) passes with a clean server join.

use ace::json::{self, Value};
use ace::pubsub::{BrokerStats, Message};
use ace::serve::client::{Client, ErrorCode, ServeError};
use ace::serve::frame::{read_frame, write_frame, FrameError, DEFAULT_MAX_FRAME};
use ace::serve::proto::{self, Envelope, Request};
use ace::serve::{probe, ServeConfig, Server};
use std::io::Write;
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

// ---------------------------------------------------------------- //
//  goldens                                                          //
// ---------------------------------------------------------------- //

#[test]
fn golden_roundtrip_every_op() {
    // publish (all fields)
    let env = proto::parse_request(
        br#"{"payload":"aGk=","requestId":"r1","retain":true,"topic":"a/b","type":"publish"}"#,
    )
    .unwrap();
    assert_eq!(
        env,
        Envelope {
            request_id: Some("r1".into()),
            req: Request::Publish {
                topic: "a/b".into(),
                payload: b"hi".to_vec(),
                retain: true,
                origin: None
            }
        }
    );
    assert_eq!(
        json::to_string(&proto::publish_ok(Some("r1"), 42.0, 2)),
        r#"{"reached":2,"requestId":"r1","timestamp":42,"type":"publish_ok"}"#
    );

    // subscribe
    let env = proto::parse_request(br#"{"filter":"a/#","requestId":"r2","type":"subscribe"}"#)
        .unwrap();
    assert_eq!(
        env.req,
        Request::Subscribe {
            filter: "a/#".into()
        }
    );
    let id = (1u64 << 40) | 1; // first subscription in shard 0
    assert_eq!(
        json::to_string(&proto::subscribe_ok(Some("r2"), 42.0, id)),
        r#"{"requestId":"r2","subscriptionId":1099511627777,"timestamp":42,"type":"subscribe_ok"}"#
    );

    // unsubscribe
    let env = proto::parse_request(
        br#"{"requestId":"r3","subscriptionId":1099511627777,"type":"unsubscribe"}"#,
    )
    .unwrap();
    assert_eq!(env.req, Request::Unsubscribe { id });
    assert_eq!(
        json::to_string(&proto::unsubscribe_ok(Some("r3"), 42.0, false)),
        r#"{"removed":false,"requestId":"r3","timestamp":42,"type":"unsubscribe_ok"}"#
    );

    // stats (the negotiation surface: v + capability list ride along)
    let env = proto::parse_request(br#"{"requestId":"r4","type":"stats"}"#).unwrap();
    assert_eq!(env.req, Request::Stats);
    let st = BrokerStats {
        pub_count: 4,
        pub_bytes: 9,
        deliver_count: 3,
        deliver_bytes: 7,
        subscriptions: 2,
    };
    assert_eq!(
        json::to_string(&proto::stats_ok(Some("r4"), 42.5, "serve", 8, &st)),
        concat!(
            r#"{"broker":"serve","#,
            r#""capabilities":["federation","origin-publish","retained-flag","scenario"],"#,
            r#""requestId":"r4","shards":8,"#,
            r#""stats":{"deliverBytes":7,"deliverCount":3,"pubBytes":9,"pubCount":4,"subscriptions":2},"#,
            r#""timestamp":42.5,"type":"stats_ok","v":1}"#
        )
    );

    // scenario (yamlite doc rides base64-encoded)
    let env = proto::parse_request(
        br#"{"requestId":"r7","scenario":"YXBwOiBtZXRybw==","type":"scenario"}"#,
    )
    .unwrap();
    assert_eq!(
        env.req,
        Request::Scenario {
            doc: "app: metro".into()
        }
    );
    assert_eq!(
        json::to_string(&proto::scenario_ok(Some("r7"), 42.0, "metro", Value::obj(vec![]))),
        r#"{"app":"metro","report":{},"requestId":"r7","timestamp":42,"type":"scenario_ok"}"#
    );

    // shutdown
    let env = proto::parse_request(br#"{"requestId":"r5","type":"shutdown"}"#).unwrap();
    assert_eq!(env.req, Request::Shutdown);
    assert_eq!(
        json::to_string(&proto::shutdown_ok(Some("r5"), 42.0)),
        r#"{"requestId":"r5","timestamp":42,"type":"shutdown_ok"}"#
    );

    // error + message push (plain, and retain-as-published)
    assert_eq!(
        json::to_string(&proto::error(Some("r6"), 42.0, "bad-json", "nope")),
        r#"{"code":"bad-json","message":"nope","requestId":"r6","timestamp":42,"type":"error"}"#
    );
    assert_eq!(
        json::to_string(&proto::message(42.0, 7, &Message::new("a/b", *b"hi"), false)),
        concat!(
            r#"{"origin":"","payload":"aGk=","subscriptionId":7,"#,
            r#""timestamp":42,"topic":"a/b","type":"message"}"#
        )
    );
    assert_eq!(
        json::to_string(&proto::message(42.0, 7, &Message::new("a/b", *b"hi"), true)),
        concat!(
            r#"{"origin":"","payload":"aGk=","retained":true,"subscriptionId":7,"#,
            r#""timestamp":42,"topic":"a/b","type":"message"}"#
        )
    );
}

// ---------------------------------------------------------------- //
//  live-server helpers                                              //
// ---------------------------------------------------------------- //

fn start_server(cfg: &ServeConfig) -> (String, thread::JoinHandle<std::io::Result<()>>) {
    let server = Server::bind("127.0.0.1:0", cfg).expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    (addr, thread::spawn(move || server.run()))
}

fn stop_server(addr: &str, handle: thread::JoinHandle<std::io::Result<()>>) {
    let mut c = Client::connect(addr).open().expect("connect for shutdown");
    c.shutdown().expect("shutdown op");
    handle.join().expect("server thread").expect("clean serve-loop exit");
}

#[test]
fn probe_passes_and_server_joins_cleanly() {
    let (addr, handle) = start_server(&ServeConfig::default());
    // the exact smoke CI runs: probe sends shutdown itself
    probe(&addr, true).expect("probe against a live server");
    handle.join().expect("server thread").expect("clean serve-loop exit");
}

#[test]
fn split_and_partial_writes_are_reassembled() {
    let (addr, handle) = start_server(&ServeConfig::default());
    let mut raw = TcpStream::connect(&addr).unwrap();
    let body = br#"{"requestId":"slow","type":"stats"}"#;
    let mut wire = (body.len() as u32).to_be_bytes().to_vec();
    wire.extend_from_slice(body);
    // one byte per write, flushed — the server's frame reader must
    // reassemble across arbitrarily fragmented reads
    for b in wire {
        raw.write_all(&[b]).unwrap();
        raw.flush().unwrap();
        thread::sleep(Duration::from_millis(1));
    }
    let resp = read_frame(&mut raw, DEFAULT_MAX_FRAME)
        .unwrap()
        .expect("a response frame");
    let v = json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
    assert_eq!(v.get("type").as_str(), Some("stats_ok"));
    assert_eq!(v.get("requestId").as_str(), Some("slow"));
    // the reply advertises the protocol version and capabilities
    assert_eq!(v.get("v").as_f64(), Some(1.0));
    let caps = v.get("capabilities").as_arr().expect("capability list");
    assert!(caps.iter().any(|c| c.as_str() == Some("scenario")));
    assert!(caps.iter().any(|c| c.as_str() == Some("federation")));
    stop_server(&addr, handle);
}

#[test]
fn oversized_frame_is_answered_and_isolated_to_its_connection() {
    let cfg = ServeConfig {
        max_frame: 1024,
        ..ServeConfig::default()
    };
    let (addr, handle) = start_server(&cfg);

    // an innocent bystander with a live subscription
    let mut bystander = Client::connect(&addr).open().unwrap();
    bystander.subscribe("news/#").unwrap();

    // the offender claims a 1 MiB frame against a 1 KiB cap
    let mut offender = TcpStream::connect(&addr).unwrap();
    offender
        .write_all(&(1_048_576u32).to_be_bytes())
        .unwrap();
    offender.flush().unwrap();
    let resp = read_frame(&mut offender, DEFAULT_MAX_FRAME)
        .unwrap()
        .expect("an error frame before the close");
    let v = json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
    assert_eq!(v.get("type").as_str(), Some("error"));
    assert_eq!(v.get("code").as_str(), Some("oversized-frame"));
    // ... then the offender's connection (and ONLY its) is closed
    match read_frame(&mut offender, DEFAULT_MAX_FRAME) {
        Ok(None) | Err(FrameError::Io(_)) => {}
        other => panic!("offender connection should be closed, got {other:?}"),
    }

    // the bystander is unaffected: publishes still flow to it
    let mut publisher = Client::connect(&addr).open().unwrap();
    assert_eq!(publisher.publish("news/x", b"still-alive", false).unwrap(), 1);
    let d = bystander
        .recv_message(Duration::from_secs(5))
        .unwrap()
        .expect("bystander delivery");
    assert_eq!(d.payload, b"still-alive");
    stop_server(&addr, handle);
}

#[test]
fn malformed_json_is_recoverable_on_the_same_connection() {
    let (addr, handle) = start_server(&ServeConfig::default());
    let mut c = Client::connect(&addr).open().unwrap();
    for garbage in [&b"{broken"[..], &b"\xff\xfe"[..], &b"[1,2,3]"[..], &b"{}"[..]] {
        c.send_raw(garbage).unwrap();
        match c.read_response().expect_err("garbage must be rejected") {
            ServeError::Protocol { code, .. } => assert!(
                [ErrorCode::BadJson, ErrorCode::BadUtf8, ErrorCode::BadEnvelope].contains(&code),
                "unexpected error code {code}"
            ),
            other => panic!("expected a protocol error, got {other:?}"),
        }
    }
    // a future protocol version is refused with a stable slug ...
    c.send_raw(br#"{"type":"stats","v":9}"#).unwrap();
    match c.read_response().expect_err("v9 must be refused") {
        ServeError::Protocol { code, .. } => assert_eq!(code, ErrorCode::UnsupportedVersion),
        other => panic!("expected a protocol error, got {other:?}"),
    }
    // ... and five rejects later, the connection still serves requests
    c.stats().expect("connection survived the garbage");
    stop_server(&addr, handle);
}

#[test]
fn retained_replay_arrives_in_retain_order_after_the_ack() {
    let cfg = ServeConfig {
        shards: 4,
        ..ServeConfig::default()
    };
    let (addr, handle) = start_server(&cfg);
    let mut publisher = Client::connect(&addr).open().unwrap();
    // distinct first levels, so the retained messages spread across
    // shards; the replay must still arrive in RETAIN order
    for i in 0..6 {
        publisher
            .publish(&format!("lvl{i}/cfg"), format!("v{i}").as_bytes(), true)
            .unwrap();
    }
    let mut late = Client::connect(&addr).open().unwrap();
    let sub_id = late.subscribe("#").unwrap();
    for i in 0..6 {
        let d = late
            .recv_message(Duration::from_secs(5))
            .unwrap()
            .unwrap_or_else(|| panic!("replay {i} missing"));
        assert_eq!(d.subscription_id, sub_id);
        assert_eq!(d.topic, format!("lvl{i}/cfg"), "replay out of retain order");
        assert_eq!(d.payload, format!("v{i}").as_bytes());
        // a replayed retained message carries the retained flag
        assert!(d.retained, "replay {i} must be flagged retained");
    }
    stop_server(&addr, handle);
}

#[test]
fn frames_written_by_the_codec_are_read_back_by_the_codec() {
    // the client and server share one codec; a zero-copy sanity pin
    // that the length prefix is big-endian and excludes itself
    let mut buf = Vec::new();
    write_frame(&mut buf, b"ping").unwrap();
    assert_eq!(&buf[..4], &4u32.to_be_bytes());
    assert_eq!(&buf[4..], b"ping");
    let mut rd = &buf[..];
    assert_eq!(read_frame(&mut rd, DEFAULT_MAX_FRAME).unwrap().unwrap(), b"ping");
}
