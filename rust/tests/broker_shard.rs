//! The sharded broker's concurrency story, pinned (DESIGN.md
//! §Broker-sharding):
//!
//! 1. DIFFERENTIAL: 200 randomized workloads (mixed wildcard/literal
//!    subscriptions, retained publishes, unsubscribes) run against a
//!    deliberately naive single-threaded reference broker — a linear
//!    scan over `topic::matches`, sharing NO code with the trie or the
//!    shard map. Per-subscriber delivery sequences (topic, payload,
//!    origin), retained-replay order, every publish's reached count,
//!    and the stats totals must be identical, and invariant across
//!    shard counts {1, 4, 16}.
//!
//! 2. STRESS: N concurrent producers x M subscribers over disjoint AND
//!    overlapping topic spaces. Per-producer sequence numbers embedded
//!    in the payloads prove nothing is lost, duplicated, or reordered
//!    per producer, and `stats()` totals exactly equal the sums the
//!    producer threads report.

use ace::pubsub::{topic, Broker, Message, SubHandle};
use ace::util::prng::Stream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::thread;

/// One observed delivery, normalized for comparison.
type Delivery = (String, Vec<u8>, String);

// ---------------------------------------------------------------- //
//  the reference broker: single-threaded, linear scan, no trie      //
// ---------------------------------------------------------------- //

struct RefSub {
    filter: String,
    alive: bool,
    log: Vec<Delivery>,
}

/// QoS-0 + retained semantics in the fewest possible moving parts.
/// Retained messages live in a Vec in retain-acceptance order
/// (last-writer-wins moves a topic to the back), which IS the global
/// `retain_seq` order the sharded broker must reproduce.
struct RefBroker {
    name: String,
    subs: Vec<RefSub>,
    retained: Vec<(String, Vec<u8>)>,
    pub_count: u64,
}

impl RefBroker {
    fn new(name: &str) -> Self {
        RefBroker {
            name: name.to_string(),
            subs: Vec::new(),
            retained: Vec::new(),
            pub_count: 0,
        }
    }

    fn subscribe(&mut self, filter: &str) {
        let mut sub = RefSub {
            filter: filter.to_string(),
            alive: true,
            log: Vec::new(),
        };
        for (t, p) in &self.retained {
            if topic::matches(filter, t) {
                sub.log.push((t.clone(), p.clone(), self.name.clone()));
            }
        }
        self.subs.push(sub);
    }

    fn publish(&mut self, name: &str, payload: &[u8], retain: bool) -> usize {
        self.pub_count += 1;
        if retain {
            self.retained.retain(|(t, _)| t != name);
            self.retained.push((name.to_string(), payload.to_vec()));
        }
        let mut reached = 0;
        let origin = self.name.clone();
        for s in self.subs.iter_mut().filter(|s| s.alive) {
            if topic::matches(&s.filter, name) {
                s.log.push((name.to_string(), payload.to_vec(), origin.clone()));
                reached += 1;
            }
        }
        reached
    }

    fn unsubscribe(&mut self, idx: usize) {
        self.subs[idx].alive = false;
    }

    fn live_subs(&self) -> usize {
        self.subs.iter().filter(|s| s.alive).count()
    }

    fn delivered(&self) -> u64 {
        self.subs.iter().map(|s| s.log.len() as u64).sum()
    }
}

// ---------------------------------------------------------------- //
//  randomized workload scripts                                      //
// ---------------------------------------------------------------- //

#[derive(Debug, Clone)]
enum Op {
    Subscribe(String),
    Publish(String, Vec<u8>, bool),
    /// Index into the subscriptions created so far (repeat
    /// unsubscribes of the same index are part of the workload).
    Unsubscribe(usize),
}

const LEVEL0: &[&str] = &["alpha", "beta", "gamma", "delta", "epsilon", "zeta"];
const DEEPER: &[&str] = &["x", "y", "z"];

fn gen_topic(rng: &mut Stream) -> String {
    let mut t = LEVEL0[rng.next_range(0, LEVEL0.len() as i64) as usize].to_string();
    for _ in 0..rng.next_range(0, 3) {
        t.push('/');
        t.push_str(DEEPER[rng.next_range(0, DEEPER.len() as i64) as usize]);
    }
    t
}

fn gen_filter(rng: &mut Stream) -> String {
    if rng.next_range(0, 10) == 0 {
        return "#".to_string();
    }
    // level 0: literal (shard-local) or `+` (wildcard shard)
    let mut f = if rng.next_range(0, 4) == 0 {
        "+".to_string()
    } else {
        LEVEL0[rng.next_range(0, LEVEL0.len() as i64) as usize].to_string()
    };
    for _ in 0..rng.next_range(0, 3) {
        f.push('/');
        match rng.next_range(0, 4) {
            0 => f.push('+'),
            1 => {
                f.push('#');
                return f;
            }
            _ => f.push_str(DEEPER[rng.next_range(0, DEEPER.len() as i64) as usize]),
        }
    }
    f
}

fn gen_ops(rng: &mut Stream, n: usize) -> Vec<Op> {
    let mut ops = Vec::with_capacity(n);
    let mut subs = 0usize;
    for _ in 0..n {
        let roll = rng.next_range(0, 100);
        if roll < 30 || subs == 0 {
            ops.push(Op::Subscribe(gen_filter(rng)));
            subs += 1;
        } else if roll < 88 {
            let payload: Vec<u8> = (0..rng.next_range(0, 16))
                .map(|_| rng.next_range(0, 256) as u8)
                .collect();
            let retain = rng.next_range(0, 4) == 0;
            ops.push(Op::Publish(gen_topic(rng), payload, retain));
        } else {
            ops.push(Op::Unsubscribe(rng.next_range(0, subs as i64) as usize));
        }
    }
    ops
}

/// Everything a workload run observes (what the differential compares).
#[derive(Debug, PartialEq)]
struct Observed {
    logs: Vec<Vec<Delivery>>,
    reached: Vec<usize>,
    pub_count: u64,
    deliver_count: u64,
    subscriptions: usize,
}

fn run_reference(ops: &[Op], name: &str) -> Observed {
    let mut b = RefBroker::new(name);
    let mut reached = Vec::new();
    for op in ops {
        match op {
            Op::Subscribe(f) => b.subscribe(f),
            Op::Publish(t, p, r) => reached.push(b.publish(t, p, *r)),
            Op::Unsubscribe(i) => b.unsubscribe(*i),
        }
    }
    Observed {
        reached,
        pub_count: b.pub_count,
        deliver_count: b.delivered(),
        subscriptions: b.live_subs(),
        logs: b.subs.into_iter().map(|s| s.log).collect(),
    }
}

fn run_sharded(ops: &[Op], name: &str, shards: usize) -> Observed {
    let b = Broker::with_shards(name, shards);
    let mut handles: Vec<SubHandle> = Vec::new();
    let mut reached = Vec::new();
    for op in ops {
        match op {
            Op::Subscribe(f) => handles.push(b.subscribe(f).expect("generated filter is valid")),
            Op::Publish(t, p, r) => reached.push(
                b.publish_opts(Message::new(t.as_str(), p.clone()), *r)
                    .expect("generated topic is valid"),
            ),
            Op::Unsubscribe(i) => b.unsubscribe(handles[*i].id),
        }
    }
    let stats = b.stats();
    Observed {
        reached,
        pub_count: stats.pub_count,
        deliver_count: stats.deliver_count,
        subscriptions: stats.subscriptions,
        logs: handles
            .iter()
            .map(|h| {
                h.rx.try_iter()
                    .map(|m| (m.topic.clone(), m.payload.to_vec(), m.origin.to_string()))
                    .collect()
            })
            .collect(),
    }
}

#[test]
fn differential_vs_reference_across_shard_counts() {
    for case in 0..200u64 {
        let mut rng = Stream::new(0x5EED_0000 + case);
        let ops = gen_ops(&mut rng, 60);
        let want = run_reference(&ops, "difftest");
        for shards in [1usize, 4, 16] {
            let got = run_sharded(&ops, "difftest", shards);
            assert_eq!(
                got, want,
                "case {case} with {shards} shards diverged from the reference\nops: {ops:#?}"
            );
        }
    }
}

/// A focused replay-order probe the randomized suite covers only
/// probabilistically: retains spread over MANY first levels (so they
/// land in different shards), then re-retain one of the earliest —
/// a late `#` subscriber must see it LAST, not in shard order.
#[test]
fn cross_shard_replay_follows_retain_order_not_shard_order() {
    let mut ops: Vec<Op> = (0..16)
        .map(|i| Op::Publish(format!("lvl{i}/cfg"), vec![i as u8], true))
        .collect();
    ops.push(Op::Publish("lvl3/cfg".into(), vec![0xFF], true)); // re-retain
    ops.push(Op::Subscribe("#".into()));
    let want = run_reference(&ops, "difftest");
    for shards in [1usize, 4, 16] {
        assert_eq!(run_sharded(&ops, "difftest", shards), want);
    }
    // and the reference itself replays lvl3 last
    let tail = want.logs[0].last().unwrap();
    assert_eq!((tail.0.as_str(), tail.1.as_slice()), ("lvl3/cfg", &[0xFF][..]));
}

// ---------------------------------------------------------------- //
//  concurrency stress                                               //
// ---------------------------------------------------------------- //

/// Parse a `"{producer}:{seq}"` payload.
fn parse_seq(payload: &[u8]) -> (usize, u64) {
    let s = std::str::from_utf8(payload).expect("stress payloads are ASCII");
    let (p, q) = s.split_once(':').expect("stress payloads are p:seq");
    (p.parse().unwrap(), q.parse().unwrap())
}

/// For one subscriber's drained log, check every producer's
/// subsequence is exactly `0..expected` in order (no loss, no dupes,
/// no reordering), and return the per-producer counts.
fn check_per_producer_order(log: &[Message], producers: usize, expected_seqs: &[Vec<u64>]) {
    let mut next_idx = vec![0usize; producers];
    for m in log {
        let (p, seq) = parse_seq(&m.payload);
        let want = expected_seqs[p]
            .get(next_idx[p])
            .unwrap_or_else(|| panic!("producer {p} delivered more than expected"));
        assert_eq!(
            seq, *want,
            "producer {p}: got seq {seq}, wanted {want} (loss, dupe, or reorder)"
        );
        next_idx[p] += 1;
    }
    for (p, idx) in next_idx.iter().enumerate() {
        assert_eq!(
            *idx,
            expected_seqs[p].len(),
            "producer {p}: incomplete delivery"
        );
    }
}

#[test]
fn concurrent_producers_lose_nothing_and_preserve_per_producer_order() {
    let producers = 8usize;
    let per = 1998usize; // divisible by 3: the overlap filter gets per/3 each
    let broker = Broker::with_shards("stress", 4);

    // M subscribers over DISJOINT spaces (one per lane) ...
    let lane_subs: Vec<SubHandle> = (0..producers)
        .map(|p| broker.subscribe(&format!("lane{p}/#")).unwrap())
        .collect();
    // ... and OVERLAPPING ones: everything, and one stage across lanes
    let all_sub = broker.subscribe("#").unwrap();
    let overlap_sub = broker.subscribe("+/s1/data").unwrap();

    let start = Arc::new(Barrier::new(producers + 1));
    let reached_total = Arc::new(AtomicU64::new(0));
    let threads: Vec<_> = (0..producers)
        .map(|p| {
            let b = broker.clone();
            let start = start.clone();
            let reached_total = reached_total.clone();
            thread::spawn(move || {
                start.wait();
                let mut published = 0u64;
                let mut reached = 0u64;
                for seq in 0..per {
                    let topic = format!("lane{p}/s{}/data", seq % 3);
                    let payload = format!("{p}:{seq}");
                    reached += b.publish(&topic, payload.as_bytes()).unwrap() as u64;
                    published += 1;
                }
                reached_total.fetch_add(reached, Ordering::Relaxed);
                published
            })
        })
        .collect();
    start.wait();
    let per_thread: Vec<u64> = threads.into_iter().map(|t| t.join().unwrap()).collect();

    // expected per-producer sequences per subscriber space
    let all_seqs: Vec<Vec<u64>> = (0..producers).map(|_| (0..per as u64).collect()).collect();
    let s1_seqs: Vec<Vec<u64>> = (0..producers)
        .map(|_| (0..per as u64).filter(|s| s % 3 == 1).collect())
        .collect();

    // disjoint lanes: lane p sees ONLY producer p, completely, in order
    for (p, sub) in lane_subs.iter().enumerate() {
        let log: Vec<Message> = sub.rx.try_iter().collect();
        assert_eq!(log.len(), per, "lane {p} lost or duplicated messages");
        let mut only_p: Vec<Vec<u64>> = vec![Vec::new(); producers];
        only_p[p] = (0..per as u64).collect();
        check_per_producer_order(&log, producers, &only_p);
    }
    // `#` sees EVERYTHING, each producer in order
    let all_log: Vec<Message> = all_sub.rx.try_iter().collect();
    assert_eq!(all_log.len(), producers * per);
    check_per_producer_order(&all_log, producers, &all_seqs);
    // the cross-lane stage filter sees exactly the s1 third
    let overlap_log: Vec<Message> = overlap_sub.rx.try_iter().collect();
    assert_eq!(overlap_log.len(), producers * per / 3);
    check_per_producer_order(&overlap_log, producers, &s1_seqs);

    // stats are EXACT, not approximate: publishes equal the sum the
    // producer threads counted; deliveries equal the sum of reached
    let stats = broker.stats();
    assert_eq!(stats.pub_count, per_thread.iter().sum::<u64>());
    assert_eq!(stats.pub_count, (producers * per) as u64);
    assert_eq!(stats.deliver_count, reached_total.load(Ordering::Relaxed));
    assert_eq!(
        stats.deliver_count,
        (producers * per * 2 + producers * per / 3) as u64,
        "lane + `#` + one third for the s1 filter"
    );
    assert_eq!(stats.subscriptions, producers + 2);
}

/// Concurrent wildcard churn: `#` subscribers joining mid-storm must
/// each see an uncorrupted per-producer prefix-sum — the publish path
/// holds its literal-shard lock across the wildcard phase precisely so
/// a joining subscriber never sees a torn (replayed AND re-delivered)
/// message. Retained publishes make the race observable.
#[test]
fn wildcard_subscribers_joining_mid_storm_never_see_duplicates() {
    let producers = 4usize;
    let per = 600usize;
    let broker = Broker::with_shards("churn", 4);
    let start = Arc::new(Barrier::new(producers + 1));

    let pubs: Vec<_> = (0..producers)
        .map(|p| {
            let b = broker.clone();
            let start = start.clone();
            thread::spawn(move || {
                start.wait();
                for seq in 0..per {
                    // retained, same topic per producer: a late joiner
                    // replays at most ONE message per producer
                    let payload = format!("{p}:{seq}");
                    b.publish_opts(
                        Message::new(format!("lane{p}/state"), payload.into_bytes()),
                        true,
                    )
                    .unwrap();
                }
            })
        })
        .collect();
    start.wait();
    // subscribers join while the storm runs
    let joiners: Vec<SubHandle> = (0..6)
        .map(|i| {
            thread::sleep(std::time::Duration::from_millis(i as u64 * 3));
            broker.subscribe("#").unwrap()
        })
        .collect();
    for t in pubs {
        t.join().unwrap();
    }
    for (i, sub) in joiners.iter().enumerate() {
        let log: Vec<Message> = sub.rx.try_iter().collect();
        // per producer: seqs must be strictly increasing (replay of a
        // retained seq followed by the SAME seq live = duplicate)
        let mut last = vec![-1i64; producers];
        for m in &log {
            let (p, seq) = parse_seq(&m.payload);
            assert!(
                (seq as i64) > last[p],
                "joiner {i}: producer {p} seq {seq} after {} — duplicate or reorder",
                last[p]
            );
            last[p] = seq as i64;
        }
    }
}
