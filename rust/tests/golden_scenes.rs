//! Cross-language golden test: the rust scene renderer must produce
//! BIT-IDENTICAL pixels to the python renderer that generated the
//! training data (see scenes.py / video::synth determinism contract).
//!
//! Requires `make artifacts` (reads artifacts/golden/*).

use ace::json;
use ace::video::synth;

/// Golden files come from `make artifacts`; when absent (offline CI
/// without the python toolchain) the tests skip instead of failing.
fn artifacts() -> Option<std::path::PathBuf> {
    let dir = ace::runtime::artifacts_dir().ok()?;
    dir.join("golden/crops.bin").exists().then_some(dir)
}

fn load_golden(dir: std::path::PathBuf) -> (json::Value, Vec<Vec<f32>>) {
    let meta = std::fs::read_to_string(dir.join("golden/scenes.json")).unwrap();
    let meta = json::parse(&meta).unwrap();
    let bin = std::fs::read(dir.join("golden/crops.bin")).unwrap();
    let n = u32::from_le_bytes(bin[0..4].try_into().unwrap()) as usize;
    let crop = u32::from_le_bytes(bin[4..8].try_into().unwrap()) as usize;
    let ch = u32::from_le_bytes(bin[8..12].try_into().unwrap()) as usize;
    assert_eq!(crop, synth::CROP);
    assert_eq!(ch, 3);
    let mut crops = Vec::with_capacity(n);
    let px = crop * crop * ch;
    for i in 0..n {
        let start = 12 + i * px * 4;
        let mut v = Vec::with_capacity(px);
        for j in 0..px {
            let o = start + j * 4;
            v.push(f32::from_le_bytes(bin[o..o + 4].try_into().unwrap()));
        }
        crops.push(v);
    }
    (meta, crops)
}

#[test]
fn rust_renderer_matches_python_bit_exactly() {
    let Some(dir) = artifacts() else {
        eprintln!("skipped: golden artifacts not built");
        return;
    };
    let (meta, crops) = load_golden(dir);
    let scenes = meta.get("scenes").as_arr().expect("scenes list");
    assert_eq!(scenes.len(), crops.len());
    assert!(scenes.len() >= 8, "golden set should cover all classes");
    for (i, (scene, py_pixels)) in scenes.iter().zip(&crops).enumerate() {
        let cls = scene.get("cls").as_usize().unwrap() as u8;
        let seed = scene.get("seed").as_usize().unwrap() as u64;
        let img = synth::make_crop(cls, seed);
        assert_eq!(
            img.data.len(),
            py_pixels.len(),
            "golden {i} size mismatch"
        );
        let mut first_bad = None;
        let mut nbad = 0;
        for (j, (r, p)) in img.data.iter().zip(py_pixels.iter()).enumerate() {
            if r.to_bits() != p.to_bits() {
                nbad += 1;
                if first_bad.is_none() {
                    first_bad = Some((j, *r, *p));
                }
            }
        }
        assert_eq!(
            nbad, 0,
            "golden {i} (cls={cls} seed={seed}): {nbad} differing pixels, first at {:?}",
            first_bad
        );
    }
}

#[test]
fn golden_covers_every_class() {
    let Some(dir) = artifacts() else {
        eprintln!("skipped: golden artifacts not built");
        return;
    };
    let (meta, _) = load_golden(dir);
    let mut seen = [false; 8];
    for s in meta.get("scenes").as_arr().unwrap() {
        seen[s.get("cls").as_usize().unwrap()] = true;
    }
    assert!(seen.iter().all(|s| *s), "classes missing from goldens: {seen:?}");
}
