//! Property-based tests over coordinator invariants (routing, batching,
//! state). proptest is unavailable offline, so this uses a small
//! deterministic fuzz harness over `util::prng` streams: 200+ random
//! cases per property, fully reproducible by seed.

use ace::deploy::{diff_plans, DeploymentPlan, Instance};
use ace::des::Scheduler;
use ace::inapp::{AdvancedPolicy, QueryPolicy};
use ace::json;
use ace::pubsub::topic;
use ace::simnet::Link;
use ace::util::prng::Stream;
use ace::util::AceId;
use ace::yamlite;

const CASES: u64 = 200;

// ---------------------------------------------------------------------------
// topic matching
// ---------------------------------------------------------------------------

fn rand_topic(s: &mut Stream, wildcards: bool) -> String {
    let levels = s.next_range(1, 5);
    let mut parts = Vec::new();
    for _ in 0..levels {
        let r = s.next_range(0, if wildcards { 10 } else { 8 });
        parts.push(match r {
            8 => "+".to_string(),
            9 => "#".to_string(),
            v => format!("l{v}"),
        });
    }
    parts.join("/")
}

#[test]
fn prop_topic_exact_name_always_matches_itself() {
    let mut s = Stream::new(1);
    for _ in 0..CASES {
        let name = rand_topic(&mut s, false);
        assert!(topic::matches(&name, &name), "{name}");
    }
}

#[test]
fn prop_hash_filter_matches_everything() {
    let mut s = Stream::new(2);
    for _ in 0..CASES {
        let name = rand_topic(&mut s, false);
        assert!(topic::matches("#", &name));
        let pref = name.split('/').next().unwrap().to_string();
        assert!(topic::matches(&format!("{pref}/#"), &name));
    }
}

#[test]
fn prop_plus_is_single_level() {
    let mut s = Stream::new(3);
    for _ in 0..CASES {
        let name = rand_topic(&mut s, false);
        let levels: Vec<&str> = name.split('/').collect();
        // replace one level with '+': must still match
        let i = s.next_range(0, levels.len() as i64) as usize;
        let mut f = levels.clone();
        f[i] = "+";
        assert!(topic::matches(&f.join("/"), &name), "{name}");
        // a filter with MORE levels never matches
        let longer = format!("{name}/extra");
        assert!(!topic::matches(&longer, &name));
    }
}

// ---------------------------------------------------------------------------
// topic trie: differential against the reference matcher
// ---------------------------------------------------------------------------

use ace::pubsub::{SymbolTable, TopicTrie};

/// The routing index and the reference scalar matcher must agree on
/// membership AND order (insertion order == linear-scan delivery
/// order) over random filter/name corpora — through both the string
/// lookup path and the pre-interned symbol-sequence hot path.
#[test]
fn prop_trie_collect_matches_agrees_with_reference() {
    for case in 0..CASES {
        let mut s = Stream::new(9_000 + case);
        let n_filters = s.next_range(1, 40) as usize;
        let mut table = SymbolTable::new();
        let mut trie = TopicTrie::new();
        let mut filters: Vec<String> = Vec::new();
        for _ in 0..n_filters {
            let f = rand_topic(&mut s, true);
            if !topic::valid_filter(&f) {
                continue; // rand wildcards can produce e.g. mid-`#`
            }
            trie.insert(&mut table, &f, filters.len());
            filters.push(f);
        }
        let mut syms: Vec<ace::pubsub::Sym> = Vec::new();
        for _ in 0..16 {
            let name = rand_topic(&mut s, false);
            let expect: Vec<usize> = filters
                .iter()
                .enumerate()
                .filter(|(_, f)| topic::matches(f, &name))
                .map(|(i, _)| i)
                .collect();
            let got: Vec<usize> =
                trie.collect_matches(&table, &name).into_iter().copied().collect();
            assert_eq!(got, expect, "case {case}: name {name} filters {filters:?}");
            // the symbol hot path (what Fabric::route uses) must agree
            table.intern_levels_into(&name, &mut syms);
            let mut got_syms: Vec<usize> = Vec::new();
            trie.for_each_match_syms(&syms, |_, v| got_syms.push(*v));
            assert_eq!(got_syms, expect, "case {case}: sym path diverged on {name}");
        }
    }
}

/// Same agreement after random removals — the trie prunes without
/// forgetting surviving subscriptions.
#[test]
fn prop_trie_remove_preserves_agreement() {
    for case in 0..CASES {
        let mut s = Stream::new(17_000 + case);
        let mut table = SymbolTable::new();
        let mut trie = TopicTrie::new();
        let mut filters: Vec<(String, bool)> = Vec::new();
        for _ in 0..20 {
            let f = rand_topic(&mut s, true);
            if !topic::valid_filter(&f) {
                continue;
            }
            trie.insert(&mut table, &f, filters.len());
            filters.push((f, true));
        }
        // remove a random half
        for (i, (f, alive)) in filters.iter_mut().enumerate() {
            if s.next_range(0, 2) == 0 {
                assert_eq!(trie.remove(&table, f, |v| *v == i), 1, "case {case}: remove {f}");
                *alive = false;
            }
        }
        assert_eq!(trie.len(), filters.iter().filter(|(_, a)| *a).count());
        for _ in 0..16 {
            let name = rand_topic(&mut s, false);
            let expect: Vec<usize> = filters
                .iter()
                .enumerate()
                .filter(|(_, (f, alive))| *alive && topic::matches(f, &name))
                .map(|(i, _)| i)
                .collect();
            let got: Vec<usize> =
                trie.collect_matches(&table, &name).into_iter().copied().collect();
            assert_eq!(got, expect, "case {case}: name {name} filters {filters:?}");
        }
    }
}

/// Directed `+`/`#` edge cases the PRNG corpus might miss.
#[test]
fn trie_wildcard_edge_cases_match_reference() {
    for (filter, names) in [
        ("a/#", &["a", "a/b", "a/b/c", "b", "ab"][..]),
        ("#", &["x", "x/y", "a/b/c/d"][..]),
        ("+", &["a", "a/b"][..]),
        ("+/+", &["a/b", "a", "a/b/c"][..]),
        ("+/#", &["a", "a/b", "a/b/c"][..]),
        ("a/+/c", &["a/b/c", "a/c", "a/b/b/c"][..]),
    ] {
        let mut table = SymbolTable::new();
        let mut trie = TopicTrie::new();
        trie.insert(&mut table, filter, ());
        for name in names {
            assert_eq!(
                !trie.collect_matches(&table, name).is_empty(),
                topic::matches(filter, name),
                "trie vs reference disagree: filter {filter}, name {name}"
            );
        }
    }
}

/// Retained-message replay: the filter-directed walk over a
/// name-keyed trie (`for_each_name_match`, what the broker does on
/// subscribe) must select exactly the names the old full scan with
/// `topic::matches` selected.
#[test]
fn prop_retained_trie_replay_agrees_with_full_scan() {
    for case in 0..CASES {
        let mut s = Stream::new(23_000 + case);
        // retained set: concrete names, last-writer-wins per name
        // (mirroring Broker::publish_opts retain semantics)
        let mut table = SymbolTable::new();
        let mut trie: TopicTrie<usize> = TopicTrie::new();
        let mut map: std::collections::HashMap<String, usize> = std::collections::HashMap::new();
        for i in 0..s.next_range(1, 40) as usize {
            let name = rand_topic(&mut s, false);
            trie.remove(&table, &name, |_| true);
            trie.insert(&mut table, &name, i);
            map.insert(name, i);
        }
        for _ in 0..16 {
            let filter = rand_topic(&mut s, true);
            if !topic::valid_filter(&filter) {
                continue;
            }
            let mut expect: Vec<usize> = map
                .iter()
                .filter(|(n, _)| topic::matches(&filter, n))
                .map(|(_, v)| *v)
                .collect();
            expect.sort_unstable();
            let mut got: Vec<usize> = Vec::new();
            trie.for_each_name_match(&table, &filter, |_, v| got.push(*v));
            got.sort_unstable();
            assert_eq!(got, expect, "case {case}: filter {filter}");
        }
    }
}

// ---------------------------------------------------------------------------
// simnet: link conservation + FIFO
// ---------------------------------------------------------------------------

#[test]
fn prop_link_deliveries_are_fifo_and_conserve_bytes() {
    let mut s = Stream::new(4);
    for case in 0..CASES {
        let mut link = Link::mbps(
            "l",
            1.0 + s.next_f32() as f64 * 99.0,
            s.next_range(0, 50_000) as f64,
        );
        let n = s.next_range(1, 30) as usize;
        let mut total = 0u64;
        let mut last_delivery = 0u64;
        let mut now = 0u64;
        for _ in 0..n {
            now += s.next_range(0, 10_000) as u64;
            let bytes = s.next_range(1, 50_000) as u64;
            total += bytes;
            let d = link.send(now, bytes);
            assert!(d > now, "case {case}: delivery not in future");
            assert!(d >= last_delivery, "case {case}: FIFO violated");
            last_delivery = d;
        }
        assert_eq!(link.bytes_sent, total, "case {case}: byte conservation");
        assert_eq!(link.msgs_sent, n as u64);
    }
}

/// Same FIFO invariant with per-message jitter enabled — the PR-3
/// regression: independent jitter samples used to let message n+1
/// overtake message n on a FIFO serialization queue.
#[test]
fn prop_jittered_link_deliveries_stay_fifo() {
    let mut s = Stream::new(44);
    for case in 0..CASES {
        let mut link = Link::mbps(
            "j",
            1.0 + s.next_f32() as f64 * 999.0,
            s.next_range(0, 50_000) as f64,
        );
        link.jitter = s.next_range(0, 100_000) as u64;
        link.jitter_seed = s.next_range(0, i64::MAX) as u64;
        let mut last_delivery = 0u64;
        let mut now = 0u64;
        for i in 0..200 {
            now += s.next_range(0, 5_000) as u64;
            let d = link.send(now, s.next_range(1, 50_000) as u64);
            assert!(d > now, "case {case} msg {i}: delivery not in future");
            assert!(
                d >= last_delivery,
                "case {case} msg {i}: jitter reordered a FIFO link ({d} < {last_delivery})"
            );
            last_delivery = d;
        }
    }
}

// ---------------------------------------------------------------------------
// DES: executes every event exactly once, in nondecreasing time
// ---------------------------------------------------------------------------

#[test]
fn prop_des_executes_all_events_in_order() {
    let mut s = Stream::new(5);
    for _ in 0..50 {
        let n = s.next_range(1, 100) as usize;
        let mut sched: Scheduler<Vec<u64>> = Scheduler::new();
        for _ in 0..n {
            let at = s.next_range(0, 1_000_000) as u64;
            sched.at(at, move |sc, w: &mut Vec<u64>| w.push(sc.now()));
        }
        let mut w = Vec::new();
        sched.run(&mut w, 10_000);
        assert_eq!(w.len(), n);
        assert!(w.windows(2).all(|p| p[0] <= p[1]), "time went backwards");
    }
}

// ---------------------------------------------------------------------------
// DES: typed-event lane vs boxed-closure lane — identical trajectories
// ---------------------------------------------------------------------------

use ace::des::SimEvent;

type Trace = Vec<(u64, u32)>;

/// Typed mirror of the boxed workload below: record (now, id), then
/// optionally chain a follow-up.
enum DiffEv {
    Emit(u32),
    Chain { delay: u64, id: u32, hops: u8 },
}

impl SimEvent<Trace> for DiffEv {
    fn fire(self, sc: &mut Scheduler<Trace, DiffEv>, w: &mut Trace) {
        match self {
            DiffEv::Emit(id) => w.push((sc.now(), id)),
            DiffEv::Chain { delay, id, hops } => {
                w.push((sc.now(), id));
                if hops > 0 {
                    sc.push_after(delay, DiffEv::Chain { delay, id, hops: hops - 1 });
                }
            }
        }
    }
}

fn chain_boxed(sc: &mut Scheduler<Trace>, w: &mut Trace, delay: u64, id: u32, hops: u8) {
    w.push((sc.now(), id));
    if hops > 0 {
        sc.after(delay, move |sc, w: &mut Trace| {
            chain_boxed(sc, w, delay, id, hops - 1)
        });
    }
}

/// The tentpole determinism guarantee: the SAME workload scheduled on
/// the typed lane and the boxed closure lane must execute the
/// identical (time, seq) interleaving — same trajectory, same event
/// count. This is what makes the svcgraph closures→typed-events
/// refactor golden-preserving: each lane's seq counter assigns the
/// same tie-breaks to the same push order.
#[test]
fn prop_typed_events_match_boxed_closure_trajectory() {
    for case in 0..CASES {
        let mut s = Stream::new(31_000 + case);
        // random seed workload: many same-time collisions (small time
        // range) + self-rescheduling chains
        let n = s.next_range(1, 60) as usize;
        // (at, id, hops, delay): collision-heavy times, hops 0 = plain
        // emit, otherwise a self-rescheduling chain
        let plan: Vec<(u64, u32, u8, u64)> = (0..n)
            .map(|i| {
                (
                    s.next_range(0, 40) as u64,
                    i as u32,
                    s.next_range(0, 4) as u8,
                    1 + s.next_range(0, 20) as u64,
                )
            })
            .collect();

        let mut typed: Scheduler<Trace, DiffEv> = Scheduler::new();
        let mut tw: Trace = Vec::new();
        for &(at, id, hops, delay) in &plan {
            if hops == 0 {
                typed.push_at(at, DiffEv::Emit(id));
            } else {
                typed.push_at(at, DiffEv::Chain { delay, id, hops });
            }
        }
        typed.run(&mut tw, 100_000);

        let mut boxed: Scheduler<Trace> = Scheduler::new();
        let mut bw: Trace = Vec::new();
        for &(at, id, hops, delay) in &plan {
            if hops == 0 {
                boxed.at(at, move |sc, w: &mut Trace| w.push((sc.now(), id)));
            } else {
                boxed.at(at, move |sc, w: &mut Trace| chain_boxed(sc, w, delay, id, hops));
            }
        }
        boxed.run(&mut bw, 100_000);

        assert_eq!(tw, bw, "case {case}: lanes diverged");
        assert_eq!(typed.executed(), boxed.executed(), "case {case}");
        assert_eq!(typed.now(), boxed.now(), "case {case}");
    }
}

// ---------------------------------------------------------------------------
// DES queues: calendar queue vs binary heap — identical pop sequences
// ---------------------------------------------------------------------------

use ace::des::queue::{CalendarQueue, EventQueue, HeapQueue};

/// The PR-6 queue-swap determinism guarantee, extended from the PR-3
/// lane differential: on random timer-dense traces — interleaved
/// pushes and pops with same-tick ties, in-wheel delays, and delays
/// spanning several wheel horizons into the overflow heap — the
/// calendar queue must report the identical `peek_time` and pop the
/// identical `(at, seq, ev)` sequence the reference binary heap does.
#[test]
fn prop_calendar_queue_matches_heap_on_random_traces() {
    for case in 0..CASES {
        let mut s = Stream::new(61_000 + case);
        let mut wheel: CalendarQueue<u64> = CalendarQueue::default();
        let mut heap: HeapQueue<u64> = HeapQueue::default();
        let mut seq = 0u64;
        let mut clock = 0u64; // pushes never target the past, like push_at's clamp
        for _ in 0..s.next_range(50, 300) {
            if s.next_range(0, 3) == 0 && !heap.is_empty() {
                assert_eq!(wheel.peek_time(), heap.peek_time(), "case {case}: peek diverged");
                let a = wheel.pop().unwrap();
                let b = heap.pop().unwrap();
                assert_eq!(a, b, "case {case}: pops diverged");
                clock = a.0;
            } else {
                // tie-heavy, in-wheel (4096 buckets x 1024 µs ≈ 4.19 s
                // horizon), a-few-horizons, and deep-overflow delays
                let delay = match s.next_range(0, 10) {
                    0..=3 => s.next_range(0, 3) as u64,
                    4..=7 => s.next_range(0, 4_000_000) as u64,
                    8 => s.next_range(0, 20_000_000) as u64,
                    _ => s.next_range(0, 100_000_000) as u64,
                };
                wheel.push(clock + delay, seq, seq);
                heap.push(clock + delay, seq, seq);
                seq += 1;
            }
        }
        loop {
            assert_eq!(wheel.peek_time(), heap.peek_time(), "case {case}: drain peek");
            let (a, b) = (wheel.pop(), heap.pop());
            assert_eq!(a, b, "case {case}: drain diverged");
            if a.is_none() {
                break;
            }
        }
    }
}

/// Same-tick batches pushed at a common `at` must pop in push (seq)
/// order whatever home the tick lands in — the current-day heap, a
/// wheel bucket, or the far-future overflow.
#[test]
fn prop_same_tick_pops_follow_push_order_in_every_home() {
    for case in 0..CASES {
        let mut s = Stream::new(63_000 + case);
        let mut q: CalendarQueue<u64> = CalendarQueue::default();
        // three bases: day 0 (current), mid-wheel, beyond the horizon
        let base = match case % 3 {
            0 => s.next_range(0, 1_000) as u64,
            1 => 1_000_000 + s.next_range(0, 1_000_000) as u64,
            _ => 10_000_000_000 + s.next_range(0, 1_000_000) as u64,
        };
        let batch = 2 + s.next_range(0, 30) as u64;
        for i in 0..batch {
            q.push(base, i, i);
        }
        for want in 0..batch {
            let (at, seq, ev) = q.pop().unwrap();
            assert_eq!((at, seq, ev), (base, want, want), "case {case}");
        }
        assert!(q.is_empty());
    }
}

/// A heartbeat population re-arming on every pop, with periods from
/// sub-day to several horizons: rollover (bucket reuse across days)
/// and overflow promotion never diverge from the reference heap.
#[test]
fn prop_heartbeat_storm_survives_many_horizon_crossings() {
    for case in 0..24 {
        let mut s = Stream::new(67_000 + case);
        let mut wheel: CalendarQueue<u64> = CalendarQueue::default();
        let mut heap: HeapQueue<u64> = HeapQueue::default();
        let timers = 1 + s.next_range(0, 64) as u64;
        let mut seq = 0u64;
        for id in 0..timers {
            let at = s.next_range(0, 1_000) as u64;
            wheel.push(at, seq, id);
            heap.push(at, seq, id);
            seq += 1;
        }
        let period = 1 + s.next_range(0, 30_000_000) as u64;
        for step in 0..2_000 {
            let a = wheel.pop().unwrap();
            let b = heap.pop().unwrap();
            assert_eq!(a, b, "case {case} step {step} (period {period})");
            wheel.push(a.0 + period, seq, a.2);
            heap.push(b.0 + period, seq, b.2);
            seq += 1;
        }
        assert_eq!(wheel.len(), timers as usize);
    }
}

// ---------------------------------------------------------------------------
// plan diffing: add/remove/replace/unchanged partition the union
// ---------------------------------------------------------------------------

fn rand_plan(s: &mut Stream, version: u64) -> DeploymentPlan {
    let n = s.next_range(0, 12) as usize;
    let mut instances: Vec<Instance> = Vec::new();
    for _ in 0..n {
        let comp = format!("c{}", s.next_range(0, 5));
        let node = AceId::parse(&format!(
            "i/ec-{}/n{}",
            s.next_range(1, 3),
            s.next_range(0, 4)
        ));
        if instances
            .iter()
            .any(|i| i.component == comp && i.node == node)
        {
            continue;
        }
        instances.push(Instance {
            id: format!("{comp}-{}", node.leaf()),
            component: comp,
            node,
            image: format!("img:{}", s.next_range(1, 3)),
        });
    }
    DeploymentPlan { app: "a".into(), version, instances }
}

#[test]
fn prop_diff_partitions_instances() {
    let mut s = Stream::new(6);
    for case in 0..CASES {
        let old = rand_plan(&mut s, 1);
        let new = rand_plan(&mut s, 2);
        let d = diff_plans(&old, &new);
        // every new instance lands in exactly one of add/replace/unchanged
        assert_eq!(
            d.add.len() + d.replace.len() + d.unchanged.len(),
            new.instances.len(),
            "case {case}"
        );
        // every old instance is either removed or still present
        assert_eq!(
            d.remove.len() + d.replace.len() + d.unchanged.len(),
            old.instances.len(),
            "case {case}"
        );
        // diff against self is a noop
        let dd = diff_plans(&new, &new);
        assert!(dd.is_noop(), "case {case}");
    }
}

// ---------------------------------------------------------------------------
// AP thresholds: band stays inside [lo0, hi0] and never inverts
// ---------------------------------------------------------------------------

#[test]
fn prop_ap_band_invariants() {
    let mut s = Stream::new(7);
    for _ in 0..CASES {
        let mut ap = AdvancedPolicy::new(0.05, 0.04);
        for _ in 0..s.next_range(0, 50) {
            if s.next_f32() < 0.5 {
                ap.observe_eoc_eil(s.next_f32() as f64 * 10.0);
            } else {
                ap.observe_coc_eil(s.next_f32() as f64 * 10.0);
            }
            let (lo, hi) = ap.thresholds();
            assert!(lo >= 0.1 - 1e-6, "lo {lo}");
            assert!(hi <= 0.8 + 1e-6, "hi {hi}");
            assert!(lo < hi, "band inverted: [{lo}, {hi}]");
        }
    }
}

// ---------------------------------------------------------------------------
// json / yamlite round trips on random documents
// ---------------------------------------------------------------------------

fn rand_value(s: &mut Stream, depth: usize) -> json::Value {
    use json::Value;
    let kind = if depth >= 3 {
        s.next_range(0, 4)
    } else {
        s.next_range(0, 6)
    };
    match kind {
        0 => Value::Null,
        1 => Value::Bool(s.next_f32() < 0.5),
        2 => Value::Num(s.next_range(-1000, 1000) as f64),
        3 => Value::Str(format!("s{}", s.next_range(0, 1000))),
        4 => {
            let n = s.next_range(0, 4) as usize;
            Value::Arr((0..n).map(|_| rand_value(s, depth + 1)).collect())
        }
        _ => {
            let n = s.next_range(0, 4) as usize;
            let mut map = std::collections::BTreeMap::new();
            for i in 0..n {
                map.insert(format!("k{i}"), rand_value(s, depth + 1));
            }
            Value::Obj(map)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut s = Stream::new(8);
    for case in 0..CASES {
        let v = rand_value(&mut s, 0);
        let text = json::to_string(&v);
        let back =
            json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}: {text}");
    }
}

#[test]
fn prop_yamlite_roundtrip_on_mappings() {
    let mut s = Stream::new(9);
    for case in 0..CASES {
        // yamlite documents are mappings at top level
        let v = match rand_value(&mut s, 1) {
            json::Value::Obj(o) if !o.is_empty() => json::Value::Obj(o),
            _ => continue,
        };
        let text = yamlite::to_string(&v);
        let back = yamlite::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}:\n{text}");
    }
}

// ---------------------------------------------------------------------------
// fault injection: seeded processes are pure, zero knobs draw nothing
// ---------------------------------------------------------------------------

use ace::simnet::faults::{link_fault_seed, FaultProcess, FaultSpec, Verdict};

/// The same fault seed must produce the IDENTICAL drop/duplicate
/// decision stream — the determinism bedrock under every chaos golden.
#[test]
fn prop_fault_decisions_are_a_pure_function_of_the_seed() {
    for case in 0..CASES {
        let mut s = Stream::new(71_000 + case);
        let seed = s.next_range(0, i64::MAX) as u64;
        let loss = s.next_f32() as f64 * 0.5;
        let dup = s.next_f32() as f64 * 0.3;
        let n = s.next_range(1, 200) as usize;
        let times: Vec<u64> = (0..n).map(|_| s.next_range(0, 1_000_000) as u64).collect();
        let mut a = FaultProcess::new(seed, loss, dup);
        let mut b = FaultProcess::new(seed, loss, dup);
        let va: Vec<Verdict> = times.iter().map(|&t| a.verdict(t)).collect();
        let vb: Vec<Verdict> = times.iter().map(|&t| b.verdict(t)).collect();
        assert_eq!(va, vb, "case {case}: same seed, different decisions");
        assert_eq!((a.lost, a.duplicated), (b.lost, b.duplicated), "case {case}");
        // verdicts depend only on the decision INDEX, not on the
        // times they were drawn at (outage windows aside): replaying
        // the stream at shifted times decides identically
        let mut c = FaultProcess::new(seed, loss, dup);
        let vc: Vec<Verdict> = times.iter().map(|&t| c.verdict(t + 1)).collect();
        assert_eq!(va, vc, "case {case}: decisions leaked wall-clock time");
        // per-link seeds keep sibling links on INDEPENDENT streams
        assert_ne!(
            link_fault_seed(seed, "up-ec0"),
            link_fault_seed(seed, "down-ec0"),
            "case {case}"
        );
    }
}

/// Zero-rate knobs must consume NO prng draws and install NO per-link
/// state — the invariant that keeps every fault-free golden
/// byte-for-byte identical to a build without the fault plane.
#[test]
fn prop_zero_rate_fault_specs_are_inert() {
    for case in 0..CASES {
        let mut s = Stream::new(73_000 + case);
        let seed = s.next_range(0, i64::MAX) as u64;
        let mut p = FaultProcess::new(seed, 0.0, 0.0);
        for _ in 0..s.next_range(1, 300) {
            let t = s.next_range(0, 1_000_000) as u64;
            assert_eq!(p.verdict(t), Verdict::Deliver, "case {case}");
        }
        assert_eq!((p.lost, p.duplicated), (0, 0));
        let spec = FaultSpec { seed, loss: 0.0, dup: 0.0 };
        assert!(!spec.is_active(), "case {case}: zero rates must be inactive");
    }
}

// ---------------------------------------------------------------------------
// at-least-once control channel: loss cannot change the converged plan
// ---------------------------------------------------------------------------

use ace::infra::{InfraBuilder, Infrastructure, NodeKind};
use ace::platform::orchestrator::NetHints;
use ace::simnet::{NetConfig, NetFabric};
use ace::svcgraph::lifecycle::{
    ControlPlane, ControlPlaneConfig, InstanceFactory, LifecycleOp, LifecycleReport,
    LifecycleScenario, ScenarioStep,
};
use ace::svcgraph::GraphRuntime;
use ace::topology::Topology;
use ace::util::secs;
use std::rc::Rc;

fn mini_infra() -> Infrastructure {
    let mut b = InfraBuilder::register("mini");
    for _ in 0..2 {
        let ec = b.claim_ec();
        b.add_edge_node(&ec, "n1", NodeKind::MiniPc, Default::default());
        b.add_edge_node(&ec, "n2", NodeKind::MiniPc, Default::default());
    }
    b.add_cloud_node("gpu-ws", NodeKind::GpuWorkstation, Default::default());
    b.build()
}

fn mini_topo() -> Topology {
    Topology::parse(
        "
app: mini
version: 1
components:
  - name: w
    image: img:1
    location: edge
    replicas: 4
    resources:
      cpu: 500
      mem: 128
    connections: []
",
    )
    .unwrap()
}

/// Deploy → fail-node → rejoin on a tiny platform-only world (no app
/// traffic: every message on the wire is an instruction, heartbeat, or
/// ack). Returns the controller's final plan plus the audit trail.
fn run_mini_plane(loss: f64, seed: u64) -> (ace::deploy::DeploymentPlan, LifecycleReport) {
    use ace::util::AceId;
    let mut net = NetFabric::new(&NetConfig { num_ecs: 2, ..Default::default() });
    if loss > 0.0 {
        net.arm_faults(FaultSpec { seed, loss, dup: 0.0 });
    }
    let hints = NetHints::from_net(&net);
    let mut rt = GraphRuntime::new(net);
    let factory: InstanceFactory = Rc::new(|_inst, _site| Ok(None));
    let node = AceId::parse("infra-mini/ec-1/n1");
    let scenario = LifecycleScenario {
        steps: vec![
            ScenarioStep { at: secs(0.0), op: LifecycleOp::Deploy(mini_topo()) },
            ScenarioStep { at: secs(10.0), op: LifecycleOp::FailNode(node.clone()) },
            ScenarioStep { at: secs(30.0), op: LifecycleOp::RejoinNode(node) },
        ],
        duration: secs(60.0),
        network: None,
        faults: None, // armed directly on the fabric above
    };
    // a LONG failure timeout (vs the 1 s heartbeat) makes a false
    // shield of a healthy node need 12+ consecutive heartbeat losses
    // (p ~ 0.2^12): the only shielded node is the scripted one, so the
    // loss run and the no-loss run see the same infrastructure history
    let cfg = ControlPlaneConfig {
        heartbeat_period_s: 1.0,
        failure_timeout_s: 12.0,
        sweep_period_s: 4.0,
        ..Default::default()
    };
    let plane =
        ControlPlane::install(&mut rt, mini_infra(), factory, None, &scenario, cfg, hints)
            .unwrap();
    rt.run_until(scenario.duration);
    (plane.plan("mini").expect("plan survives the run"), plane.report())
}

/// Under 20% instruction loss the ack/retry channel must converge
/// every node to EXACTLY the plan a lossless run converges to — loss
/// changes timing and retry counts, never placement intent.
#[test]
fn prop_ack_retry_converges_to_the_no_loss_plan_under_20pct_loss() {
    let (baseline_plan, baseline_report) = run_mini_plane(0.0, 0);
    assert_eq!(baseline_report.retries, 0, "no loss, no retries");
    assert_eq!(baseline_report.dup_suppressed, 0);
    let mut total_retries = 0;
    let mut total_convergences = 0;
    for case in 0..20 {
        let (plan, report) = run_mini_plane(0.2, 1000 + case);
        assert_eq!(
            plan, baseline_plan,
            "case {case}: loss changed the converged plan"
        );
        // the scripted fail/rejoin episodes were noticed and survived
        assert!(
            report.shielded.iter().any(|n| n.ends_with("ec-1/n1")),
            "case {case}: failed node never shielded"
        );
        assert!(
            report.events.iter().any(|(_, e)| e.contains("rejoin: node")),
            "case {case}: rejoin missing"
        );
        total_retries += report.retries;
        total_convergences += report.convergence_us.len();
        // and the run is replay-identical under the same fault seed
        let (plan2, report2) = run_mini_plane(0.2, 1000 + case);
        assert_eq!(plan, plan2, "case {case}: fault seed not deterministic");
        assert_eq!(report.hash(), report2.hash(), "case {case}");
    }
    assert!(
        total_retries > 0,
        "20% loss over 20 seeded runs never forced a retry"
    );
    assert!(
        total_convergences > 0,
        "no fault episode ever converged across the sweep"
    );
}

// ---------------------------------------------------------------------------
// classifier batching: the splitting loop always covers all crops
// ---------------------------------------------------------------------------

#[test]
fn prop_batch_splitting_covers_all_crops() {
    let mut s = Stream::new(10);
    let sizes = [1usize, 2, 4, 8, 16];
    for _ in 0..CASES {
        let n = s.next_range(1, 200) as usize;
        let mut covered = 0;
        let mut execs = 0;
        while covered < n {
            let remaining = n - covered;
            let mut b = sizes[0];
            for &x in &sizes {
                if x <= remaining {
                    b = x;
                }
            }
            covered += b.min(remaining);
            execs += 1;
            assert!(execs < 400, "no progress");
        }
        assert_eq!(covered, n);
    }
}
