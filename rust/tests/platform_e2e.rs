//! Platform end-to-end integration: registration -> bridged services ->
//! topology submission -> orchestration -> deployment -> monitoring ->
//! incremental update -> failure shielding -> removal. Exercises the
//! whole Figure 1 lifecycle over real (threaded) brokers and agents —
//! no artifacts required.

use ace::infra::agent::Agent;
use ace::infra::{paper_testbed, NodeStatus};
use ace::platform::api::{kinds, ApiServer};
use ace::platform::controller::{record_heartbeat, Controller};
use ace::platform::Monitor;
use ace::pubsub::{Bridge, Broker};
use ace::storage::{FileService, Lifecycle, ObjectStore};
use ace::topology::{Topology, VIDEOQUERY_TOPOLOGY};
use std::collections::BTreeMap;
use std::time::Duration;

fn wait_for<F: Fn() -> bool>(what: &str, f: F) {
    for _ in 0..500 {
        if f() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timeout waiting for: {what}");
}

#[test]
fn full_lifecycle_on_paper_testbed() {
    // --- user registration (§4.3.1): infra + per-cluster brokers ---
    let mut infra = paper_testbed("e2e");
    let brokers: BTreeMap<String, Broker> = infra
        .clusters()
        .map(|c| (c.id.leaf().to_string(), Broker::new(c.id.leaf())))
        .collect();
    // long-lasting EC<->CC bridges (Figure 2 link ②)
    let _bridges: Vec<Bridge> = infra
        .ecs
        .iter()
        .map(|ec| {
            Bridge::start(
                &brokers[ec.id.leaf()],
                &brokers["cc"],
                &["cloud/#", "svc/#"],
                &["edge/#"],
            )
            .unwrap()
        })
        .collect();

    // agents on every node
    let agents: Vec<Agent> = infra
        .all_nodes()
        .map(|(c, n)| Agent::start(n.id.clone(), brokers[c.id.leaf()].clone()).unwrap())
        .collect();
    assert_eq!(agents.len(), 13);

    // --- platform services ---
    let api = ApiServer::new();
    let monitor = Monitor::start(api.clone(), &brokers).unwrap();
    let ctl = Controller::new(api.clone(), brokers.clone());

    // --- application deployment (Figure 4) ---
    let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
    let plan = ctl.deploy(&topo, &infra).unwrap();
    assert_eq!(plan.instances.len(), 9 + 9 + 3 + 3 + 3); // dg+od+eoc+lic+cc trio

    // every camera node ends up running dg + od
    wait_for("od+dg on camera nodes", || {
        agents
            .iter()
            .filter(|a| {
                let r = a.running();
                r.iter().any(|x| x.component == "od") && r.iter().any(|x| x.component == "dg")
            })
            .count()
            == 9
    });

    // monitoring sees component health
    wait_for("monitor health", || {
        let h = monitor.component_health();
        h.get("od").map(|x| x.running).unwrap_or(0) == 9
            && h.get("coc").map(|x| x.running).unwrap_or(0) == 1
    });

    // --- resource-level file service over the bridged message bus ---
    let cc_files = FileService::new(ObjectStore::new(), brokers["cc"].clone(), "cc");
    let sub = brokers["ec-1"].subscribe("svc/file/cc/#");
    // control-plane announcements flow cc -> ec over the bridge? The
    // bridge forwards edge->cc for svc/#; cc->ec only edge/#. So watch
    // on the CC broker directly:
    drop(sub);
    let cc_sub = brokers["cc"].subscribe("svc/file/cc/#").unwrap();
    cc_files.put("models", "eoc-v1", vec![7u8; 4096], Lifecycle::Permanent);
    let msg = cc_sub.rx.recv_timeout(Duration::from_secs(2)).unwrap();
    assert!(msg.utf8().contains("eoc-v1"));

    // --- incremental update: bump od image only (§4.4.3) ---
    let mut topo2 = topo.clone();
    topo2.version = 2;
    for c in &mut topo2.components {
        if c.name == "od" {
            c.image = "ace/object-detector:2".into();
        }
    }
    let (_p2, touched) = ctl.update_incremental(&topo2, &infra).unwrap();
    assert_eq!(touched, 9);
    wait_for("od image updated", || {
        agents
            .iter()
            .flat_map(|a| a.running())
            .filter(|r| r.component == "od" && r.image == "ace/object-detector:2")
            .count()
            == 9
    });

    // --- heartbeats + failure shielding (§4.2.1) ---
    for (_, n) in infra.all_nodes() {
        record_heartbeat(&api, &n.id, 10_000, ace::json::Value::obj(vec![]));
    }
    // one node goes silent: its heartbeat is old
    let victim = infra.ecs[1].nodes[2].id.clone();
    record_heartbeat(&api, &victim, 1_000, ace::json::Value::obj(vec![]));
    let shielded = ctl.shield_failed(&mut infra, 5_000);
    assert_eq!(shielded, vec![victim.clone()]);
    assert_eq!(infra.find_node(&victim).unwrap().status, NodeStatus::Failed);
    // redeploying (thorough update) avoids the failed node
    let plan3 = ctl.update_thorough(&topo2, &infra).unwrap();
    assert!(plan3.instances.iter().all(|i| i.node != victim));
    assert_eq!(plan3.instances_of("od").len(), 8);

    // --- removal converges agents to empty ---
    ctl.remove("videoquery").unwrap();
    wait_for("all agents empty", || {
        agents.iter().all(|a| a.running().is_empty())
    });
    assert!(api.get(kinds::PLAN, "videoquery").is_none());
}

#[test]
fn ec_autonomy_survives_wan_partition() {
    // Principle Two: "edges should be able to cache data and provide
    // partial services autonomously to mitigate the impact of network
    // partitioning." The EC's broker, file service, and running
    // components must keep working while the EC<->CC bridge is down,
    // and cloud-bound traffic resumes after reconnection.
    let ec = Broker::new("ec-1");
    let cc = Broker::new("cc");
    let bridge = Bridge::start(&ec, &cc, &["cloud/#"], &["edge/#"]).unwrap();

    // an edge component + local file service
    let node = ace::util::AceId::parse("infra-p2/ec-1/rpi1");
    let agent = Agent::start(node.clone(), ec.clone()).unwrap();
    let ec_files = FileService::new(ObjectStore::new(), ec.clone(), "ec-1");
    let instr = ace::infra::agent::compose_instruction(
        "vq",
        &[("od-1".into(), "od".into(), "img".into())],
    );
    ec.publish(&ace::infra::agent::deploy_topic(&node), instr.into_bytes())
        .unwrap();
    wait_for("component running", || agent.running().len() == 1);

    let cc_sub = cc.subscribe("cloud/#").unwrap();
    ec.publish("cloud/results/1", b"pre-partition".to_vec()).unwrap();
    assert_eq!(
        cc_sub.rx.recv_timeout(Duration::from_secs(2)).unwrap().utf8(),
        "pre-partition"
    );

    // --- WAN partition: the long-lasting link goes down ---
    bridge.shutdown();

    // edge-local services keep working (autonomy)
    let local_sub = ec.subscribe("local/alerts").unwrap();
    ec.publish("local/alerts", b"edge-side alert".to_vec()).unwrap();
    assert_eq!(
        local_sub.rx.recv_timeout(Duration::from_secs(2)).unwrap().utf8(),
        "edge-side alert"
    );
    ec_files.put("cache", "crop-1", vec![1u8; 512], Lifecycle::Temporary);
    assert_eq!(ec_files.get("cache", "crop-1").unwrap().len(), 512);
    // the deployed component is untouched
    assert_eq!(agent.running().len(), 1);
    // but cloud-bound traffic does NOT arrive
    ec.publish("cloud/results/2", b"lost".to_vec()).unwrap();
    assert!(cc_sub.rx.recv_timeout(Duration::from_millis(200)).is_err());

    // --- reconnection: a fresh bridge restores the cloud path ---
    let _bridge2 = Bridge::start(&ec, &cc, &["cloud/#"], &["edge/#"]).unwrap();
    ec.publish("cloud/results/3", b"post-reconnect".to_vec()).unwrap();
    assert_eq!(
        cc_sub.rx.recv_timeout(Duration::from_secs(2)).unwrap().utf8(),
        "post-reconnect"
    );
}

#[test]
fn two_apps_share_one_infrastructure() {
    // Principle Three: co-located applications contend for resources
    let mut infra = paper_testbed("multi");
    let brokers: BTreeMap<String, Broker> = infra
        .clusters()
        .map(|c| (c.id.leaf().to_string(), Broker::new(c.id.leaf())))
        .collect();
    let ctl = Controller::new(ApiServer::new(), brokers);

    let app1 = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
    let plan1 = ace::platform::orchestrator::place_onto(&app1, &mut infra).unwrap();
    assert!(!plan1.instances.is_empty());

    // a second, CC-heavy app still fits (CC has 32 cores, coc used 16)
    let app2 = Topology::parse(
        "
app: analytics
components:
  - name: batch
    location: cloud
    resources:
      cpu: 8000
      mem: 4096
",
    )
    .unwrap();
    let plan2 = ace::platform::orchestrator::place_onto(&app2, &mut infra).unwrap();
    assert_eq!(plan2.instances.len(), 1);

    // but a third greedy one does not
    let app3 = Topology::parse(
        "
app: hog
components:
  - name: eater
    location: cloud
    resources:
      cpu: 16000
      mem: 4096
",
    )
    .unwrap();
    assert!(ace::platform::orchestrator::place_onto(&app3, &mut infra).is_err());
    let _ = ctl;
}
