//! AOT round-trip integration tests: artifacts built by python
//! (`make artifacts`) must load, compile, and reproduce python's own
//! numerics through the rust PJRT runtime.
//!
//! Compiled only with the `pjrt` feature (the offline default build has
//! no XLA backend; see runtime/backend_stub.rs).
#![cfg(feature = "pjrt")]

use ace::runtime::{artifacts_dir, Engine, ModelBank};
use ace::video::od;
use ace::{json, runtime};

fn load_bank() -> (Engine, ModelBank) {
    let engine = Engine::cpu().expect("PJRT cpu client");
    let dir = artifacts_dir().expect("run `make artifacts` first");
    let bank = ModelBank::load(&engine, &dir).expect("load model bank");
    (engine, bank)
}

fn load_goldens() -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let dir = artifacts_dir().unwrap();
    let meta = std::fs::read_to_string(dir.join("golden/scenes.json")).unwrap();
    let meta = json::parse(&meta).unwrap();
    let bin = std::fs::read(dir.join("golden/crops.bin")).unwrap();
    let n = u32::from_le_bytes(bin[0..4].try_into().unwrap()) as usize;
    let crop = u32::from_le_bytes(bin[4..8].try_into().unwrap()) as usize;
    let px = crop * crop * 3;
    let mut crops = Vec::new();
    for i in 0..n {
        let start = 12 + i * px * 4;
        crops.push(
            (0..px)
                .map(|j| {
                    let o = start + j * 4;
                    f32::from_le_bytes(bin[o..o + 4].try_into().unwrap())
                })
                .collect::<Vec<f32>>(),
        );
    }
    let probs = |key: &str| -> Vec<Vec<f32>> {
        meta.get(key)
            .as_arr()
            .unwrap()
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_f64().unwrap() as f32)
                    .collect()
            })
            .collect()
    };
    (crops, probs("eoc_probs"), probs("coc_probs"))
}

#[test]
fn manifest_loads_with_expected_models() {
    let (_e, bank) = load_bank();
    assert_eq!(bank.manifest.crop, 32);
    assert_eq!(bank.manifest.classes.len(), 8);
    assert_eq!(bank.manifest.classes[bank.manifest.target_class], "motorcycle");
    assert_eq!(bank.eoc.outputs, 2);
    assert_eq!(bank.coc.outputs, 8);
    assert!(bank.eoc.batch_sizes.contains(&1));
    // both models must be usable; the capacity asymmetry (the paper's
    // ResNet152-vs-MobileNetV2 gap) shows in the parameter counts —
    // accuracies are not directly comparable (8-class top-1 vs binary)
    let eoc_acc = bank.manifest.models["eoc"].accuracy;
    let coc_acc = bank.manifest.models["coc"].accuracy;
    assert!(coc_acc > 0.85, "COC top-1 {coc_acc}");
    assert!(eoc_acc > 0.7, "EOC binary acc {eoc_acc}");
    assert!(
        bank.manifest.models["coc"].params > 30 * bank.manifest.models["eoc"].params,
        "model capacity asymmetry lost"
    );
}

#[test]
fn rust_inference_matches_python_goldens() {
    let (_e, bank) = load_bank();
    let (crops, eoc_want, coc_want) = load_goldens();
    let eoc_got = bank.eoc.classify(&crops).unwrap();
    let coc_got = bank.coc.classify(&crops).unwrap();
    for (i, (got, want)) in eoc_got.iter().zip(&eoc_want).enumerate() {
        for (g, w) in got.iter().zip(want) {
            assert!(
                (g - w).abs() < 2e-4,
                "eoc golden {i}: got {got:?} want {want:?}"
            );
        }
    }
    for (i, (got, want)) in coc_got.iter().zip(&coc_want).enumerate() {
        for (g, w) in got.iter().zip(want) {
            assert!(
                (g - w).abs() < 2e-4,
                "coc golden {i}: got {got:?} want {want:?}"
            );
        }
    }
}

#[test]
fn batching_is_output_invariant() {
    // the same crops through b=1 and the largest batch must agree
    let (_e, bank) = load_bank();
    let (crops, _, _) = load_goldens();
    let one_by_one: Vec<Vec<f32>> = crops
        .iter()
        .map(|c| bank.coc.classify(std::slice::from_ref(c)).unwrap().remove(0))
        .collect();
    let batched = bank.coc.classify(&crops).unwrap();
    for (i, (a, b)) in one_by_one.iter().zip(&batched).enumerate() {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 2e-4, "crop {i}: {a:?} vs {b:?}");
        }
    }
}

#[test]
fn probabilities_are_normalized() {
    let (_e, bank) = load_bank();
    let (crops, _, _) = load_goldens();
    for probs in bank.coc.classify(&crops).unwrap() {
        let s: f32 = probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "sum={s}");
        assert!(probs.iter().all(|p| (0.0..=1.0).contains(p)));
    }
}

#[test]
fn framediff_artifact_matches_native_od() {
    let engine = Engine::cpu().unwrap();
    let dir = artifacts_dir().unwrap();
    let bank = ModelBank::load(&engine, &dir).unwrap();
    let (h, w) = (bank.manifest.frame_h, bank.manifest.frame_w);
    let exe = engine.load(&dir.join(&bank.manifest.framediff_file)).unwrap();
    // three synthetic frames with motion
    let mut cam = ace::video::CameraStream::new(77, 2);
    cam.advance_to(1.2);
    let f0 = cam.frame_at(1.0).gray();
    let f1 = cam.frame_at(1.1).gray();
    let f2 = cam.frame_at(1.2).gray();
    let lits: Vec<ace::runtime::Literal> = [&f0, &f1, &f2]
        .iter()
        .map(|f| runtime::literal_f32(f, &[h as i64, w as i64]).unwrap())
        .collect();
    let out = exe.run(&lits).unwrap();
    let xla_map = out[0].to_vec::<f32>().unwrap();
    let native = od::motion_map(&f0, &f1, &f2, h, w);
    assert_eq!(xla_map.len(), native.len());
    for (i, (a, b)) in xla_map.iter().zip(&native).enumerate() {
        assert!((a - b).abs() < 1e-5, "pixel {i}: xla {a} vs native {b}");
    }
}

#[test]
fn calibration_measures_positive_batch_times() {
    let (_e, mut bank) = load_bank();
    bank.coc.calibrate(3).unwrap();
    bank.eoc.calibrate(3).unwrap();
    // every exported batch size gets a positive measured service time,
    // and total batch time grows with batch size
    for clf in [&bank.coc, &bank.eoc] {
        let mut prev = 0.0;
        for &b in &clf.batch_sizes {
            let t = clf.service_time(b);
            assert!(t > 0.0, "{} batch {b}", clf.name);
            assert!(t >= prev * 0.8, "{} batch {b} faster than smaller batch", clf.name);
            prev = t;
        }
    }
    // the tiny EOC amortizes per-crop cost at small batches; the COC's
    // interpret-mode pallas grid makes its batching super-linear (see
    // EXPERIMENTS.md §Perf L1) — the DES therefore serves COC per-crop,
    // which is also the paper's 32.3 ms/crop operating mode.
    let eoc_b2 = bank.eoc.service_time(2) / 2.0;
    let eoc_b1 = bank.eoc.service_time(1);
    assert!(
        eoc_b2 < eoc_b1 * 1.3,
        "EOC b2 per-crop {eoc_b2} should be near/below b1 {eoc_b1}"
    );
}

#[test]
fn fl_train_step_artifact_runs_and_learns() {
    let engine = Engine::cpu().unwrap();
    let dir = artifacts_dir().unwrap();
    let bank = ModelBank::load(&engine, &dir).unwrap();
    let exe = engine.load(&dir.join(&bank.manifest.fl_file)).unwrap();
    let d = bank.manifest.fl_dim;
    let bsz = bank.manifest.fl_batch;
    // linearly separable toy data: y = x[0] > 0
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..bsz {
        let v = if i % 2 == 0 { 1.0f32 } else { -1.0 };
        x.push(v);
        x.extend(std::iter::repeat(0.1f32).take(d - 1));
        y.push(if v > 0.0 { 1i32 } else { 0 });
    }
    let mut w = vec![0.0f32; d * 2];
    let mut b = vec![0.0f32; 2];
    let mut last_loss = f32::INFINITY;
    for step in 0..10 {
        let args = vec![
            runtime::literal_f32(&w, &[d as i64, 2]).unwrap(),
            runtime::literal_f32(&b, &[2]).unwrap(),
            runtime::literal_f32(&x, &[bsz as i64, d as i64]).unwrap(),
            runtime::literal_i32(&y, &[bsz as i64]).unwrap(),
            runtime::literal_f32(&[0.5], &[]).unwrap(),
        ];
        let out = exe.run(&args).unwrap();
        w = out[0].to_vec::<f32>().unwrap();
        b = out[1].to_vec::<f32>().unwrap();
        let loss = out[2].to_vec::<f32>().unwrap()[0];
        if step > 0 {
            assert!(loss <= last_loss + 1e-3, "loss rose at step {step}: {loss} > {last_loss}");
        }
        last_loss = loss;
    }
    assert!(last_loss < 0.4, "loss did not drop: {last_loss}");
}
