//! Acceptance goldens for the virtual-time control plane (DESIGN.md
//! §Control-plane): a scripted deploy → incremental-update →
//! node-failure → shield/redeploy → remove scenario replays
//! bit-identically, and both applications survive lifecycle ops
//! mid-run. (The untouched-component `(at, seq)` trajectory property
//! is pinned by unit tests in `svcgraph::tests`.)
//!
//! No artifacts required (synthetic compute).

use ace::app::fedtrain::{run_fedtrain_scenario, FedConfig};
use ace::app::videoquery::{run_scenario, CellConfig, Compute, Paradigm, ServiceTimes};
use ace::metrics::CellMetrics;
use ace::simnet::faults::FaultSpec;
use ace::svcgraph::lifecycle::{LifecycleReport, LifecycleScenario};
use ace::topology::Topology;

/// The canonical lifecycle script shipped with the CLI
/// (`ace svcrun --scenario scenarios/videoquery_lifecycle.yaml`):
/// parsing it here keeps the example honest.
const VIDEOQUERY_SCENARIO: &str = include_str!("../scenarios/videoquery_lifecycle.yaml");

/// The chaos script: fail → rejoin → rebalance under 10% seeded loss
/// (`ace svcrun --scenario scenarios/videoquery_churn.yaml`).
const VIDEOQUERY_CHURN: &str = include_str!("../scenarios/videoquery_churn.yaml");

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

/// Trajectory digest: everything observable from a scenario run — the
/// control plane's full audit trail plus the application metrics.
fn outcome_hash(m: &CellMetrics, report: &LifecycleReport) -> u64 {
    let mut h = report.hash();
    fnv(&mut h, &m.crops.to_le_bytes());
    fnv(&mut h, &m.bwc_bytes.to_le_bytes());
    fnv(&mut h, &m.edge_decided.to_le_bytes());
    fnv(&mut h, &m.cloud_decided.to_le_bytes());
    for v in [m.f1.tp, m.f1.fp, m.f1.fn_, m.f1.tn] {
        fnv(&mut h, &v.to_le_bytes());
    }
    fnv(&mut h, &m.eil.mean().to_bits().to_le_bytes());
    h
}

fn vq_cfg() -> CellConfig {
    CellConfig {
        paradigm: Paradigm::AceBp,
        interval_s: 0.3,
        duration_s: 40.0, // sampling horizon; the scenario runs to 44
        seed: 7,
        ..Default::default()
    }
}

fn run_vq() -> (CellMetrics, LifecycleReport) {
    let scenario = LifecycleScenario::parse(VIDEOQUERY_SCENARIO).unwrap();
    let out = run_scenario(
        vq_cfg(),
        ServiceTimes::synthetic(),
        Compute::Synthetic { target_bias: 0.05 },
        &scenario,
    )
    .unwrap();
    (out.metrics, out.report)
}

#[test]
fn videoquery_lifecycle_golden_is_deterministic_and_complete() {
    let (m1, r1) = run_vq();

    // the app actually ran: crops were produced and decided both ways
    assert!(m1.crops > 50, "only {} crops", m1.crops);
    assert!(m1.edge_decided > 0);
    assert!(m1.bwc_bytes > 0, "platform + app traffic must cross the WAN");

    // ② deploy: 27 modelled instances came up through agents
    assert!(r1.spawned >= 27, "spawned only {}", r1.spawned);
    // ③ incremental update: the od image bump redeployed exactly the
    // camera nodes (9 replaces show up as retire+spawn pairs)
    assert!(
        r1.events.iter().any(|(_, e)| e.contains("update 'videoquery' v2")),
        "update op missing from the audit trail"
    );
    let od_restarts = r1
        .events
        .iter()
        .filter(|(_, e)| e.contains("started") && e.contains("ace/object-detector:2"))
        .count();
    assert_eq!(od_restarts, 9, "every camera node must restart od on v2");

    // ④ failure → shield → redeploy: the minipc crash is noticed via
    // missed heartbeats, the node is shielded, eoc/lic re-place
    assert!(
        r1.shielded.iter().any(|n| n.ends_with("ec-1/minipc")),
        "minipc not shielded: {:?}",
        r1.shielded
    );
    assert!(r1.redeploys >= 1, "shield must trigger a redeploy");
    assert!(
        r1.events
            .iter()
            .any(|(_, e)| e.contains("shield/redeploy 'videoquery'")),
        "redeploy missing from the audit trail"
    );
    // the re-placed eoc came up on a surviving EC-1 node (an rpi)
    assert!(
        r1.events
            .iter()
            .any(|(at, e)| *at > ace::util::secs(24.0)
                && e.contains("started 'eoc-ec-1-")
                && !e.contains("minipc")),
        "eoc was not re-placed onto a surviving node"
    );

    // remove: everything the agents started was wound down again
    // (instances that died with the node count as retired too)
    assert_eq!(r1.spawned, r1.retired, "leaked instances after remove");
    assert!(r1.status_reports > 100, "heartbeats must keep flowing");

    // the golden: a second full run produces the identical trajectory
    let (m2, r2) = run_vq();
    assert_eq!(
        outcome_hash(&m1, &r1),
        outcome_hash(&m2, &r2),
        "lifecycle scenario must replay bit-identically"
    );
    assert_eq!(r1.events, r2.events);
}

/// Acceptance: with every fault knob at ZERO the fault plane draws
/// nothing and allocates nothing, so an armed-but-inert spec replays
/// the existing lifecycle golden byte for byte.
#[test]
fn zero_fault_knobs_replay_the_lifecycle_golden_byte_for_byte() {
    let (m1, r1) = run_vq();
    let mut scenario = LifecycleScenario::parse(VIDEOQUERY_SCENARIO).unwrap();
    scenario.faults = Some(FaultSpec { seed: 99, loss: 0.0, dup: 0.0 });
    let out = run_scenario(
        vq_cfg(),
        ServiceTimes::synthetic(),
        Compute::Synthetic { target_bias: 0.05 },
        &scenario,
    )
    .unwrap();
    assert_eq!(out.report.msgs_lost, 0);
    assert_eq!(out.report.retries, 0);
    assert_eq!(out.report.dup_suppressed, 0);
    assert_eq!(
        outcome_hash(&m1, &r1),
        outcome_hash(&out.metrics, &out.report),
        "a zero-rate fault spec must be invisible"
    );
    assert_eq!(r1.events, out.report.events);
}

fn run_vq_churn() -> (CellMetrics, LifecycleReport) {
    let scenario = LifecycleScenario::parse(VIDEOQUERY_CHURN).unwrap();
    let out = run_scenario(
        vq_cfg(),
        ServiceTimes::synthetic(),
        Compute::Synthetic { target_bias: 0.05 },
        &scenario,
    )
    .unwrap();
    (out.metrics, out.report)
}

#[test]
fn videoquery_survives_fail_rejoin_rebalance_under_loss() {
    let (m1, r1) = run_vq_churn();

    // chaos actually bit: the fault plane dropped messages, and the
    // at-least-once channel had to work for its convergence
    assert!(r1.msgs_lost > 0, "10% loss dropped nothing");
    assert!(r1.retries > 0, "loss never forced an instruction retry");
    assert!(
        r1.events.iter().any(|(_, e)| e.contains("link up-ec0 down")),
        "fail-link op missing from the audit trail"
    );

    // fail → shield → re-place on a survivor
    assert!(
        r1.shielded.iter().any(|n| n.ends_with("ec-1/minipc")),
        "minipc not shielded: {:?}",
        r1.shielded
    );
    assert!(r1.redeploys >= 1, "shield must trigger a redeploy");

    // rejoin: agent restarted, apps re-placed around the capacity
    assert!(
        r1.events
            .iter()
            .any(|(_, e)| e.contains("rejoin: node") && e.contains("ec-1/minipc")),
        "rejoin missing from the audit trail"
    );
    assert!(
        r1.events
            .iter()
            .any(|(_, e)| e.contains("rejoin/rebalance 'videoquery'")),
        "rejoin must re-place the app"
    );

    // every fault episode converged: all outstanding instructions were
    // acked, and the convergence-time metric recorded it
    assert!(
        !r1.convergence_us.is_empty(),
        "no fault episode ever converged"
    );
    assert!(r1.max_convergence_ms().unwrap() > 0.0);

    // the app survived the whole cycle and remove wound everything down
    assert!(m1.crops > 50, "only {} crops", m1.crops);
    assert_eq!(r1.spawned, r1.retired, "leaked instances after remove");

    // the golden: the chaos trajectory replays bit-identically
    let (m2, r2) = run_vq_churn();
    assert_eq!(
        outcome_hash(&m1, &r1),
        outcome_hash(&m2, &r2),
        "chaos scenario must replay bit-identically"
    );
    assert_eq!(r1.events, r2.events);
    assert_eq!(r1.convergence_us, r2.convergence_us);
}

/// Acceptance for the laned scheduler (DESIGN.md §Parallel-DES): the
/// sequential k-way merge pops in global `(at, seq)` order whatever
/// the lane count, so `--partitions 2/4` replays BOTH apps' lifecycle
/// goldens byte for byte — audit trail, metrics, and chaos included.
#[test]
fn lifecycle_goldens_replay_byte_for_byte_under_partitioned_lanes() {
    let (m1, r1) = run_vq();
    let base = outcome_hash(&m1, &r1);
    let scenario = LifecycleScenario::parse(VIDEOQUERY_SCENARIO).unwrap();
    let churn = LifecycleScenario::parse(VIDEOQUERY_CHURN).unwrap();
    let (mc1, rc1) = run_vq_churn();
    let churn_base = outcome_hash(&mc1, &rc1);
    for partitions in [2, 4] {
        let out = run_scenario(
            CellConfig { partitions, ..vq_cfg() },
            ServiceTimes::synthetic(),
            Compute::Synthetic { target_bias: 0.05 },
            &scenario,
        )
        .unwrap();
        assert_eq!(
            base,
            outcome_hash(&out.metrics, &out.report),
            "--partitions {partitions}: videoquery lifecycle golden diverged"
        );
        assert_eq!(r1.events, out.report.events);

        // the seeded-chaos trajectory too: fault draws ride the same
        // merged event order, so loss/dup land on identical messages
        let out = run_scenario(
            CellConfig { partitions, ..vq_cfg() },
            ServiceTimes::synthetic(),
            Compute::Synthetic { target_bias: 0.05 },
            &churn,
        )
        .unwrap();
        assert_eq!(
            churn_base,
            outcome_hash(&out.metrics, &out.report),
            "--partitions {partitions}: videoquery chaos golden diverged"
        );
    }

    let (mf, rf) = run_fedtrain_scenario(fed_cfg(), &fed_scenario()).unwrap();
    for partitions in [2, 4] {
        let (m2, r2) =
            run_fedtrain_scenario(FedConfig { partitions, ..fed_cfg() }, &fed_scenario()).unwrap();
        assert_eq!(
            rf.hash(),
            r2.hash(),
            "--partitions {partitions}: fedtrain audit trail diverged"
        );
        assert_eq!(mf.final_accuracy.to_bits(), m2.final_accuracy.to_bits());
        assert_eq!(mf.rounds.len(), m2.rounds.len());
    }
}

fn fed_topo(replicas: usize, version: u64) -> Topology {
    Topology::parse(&format!(
        "
app: fedtrain
version: {version}
components:
  - name: trainer
    image: ace/fl-trainer:1
    location: edge
    replicas: {replicas}
    resources:
      cpu: 2000
      mem: 1024
    connections: [coordinator]
  - name: coordinator
    image: ace/fl-coordinator:1
    location: cloud
    resources:
      cpu: 4000
      mem: 2048
    connections: []
"
    ))
    .unwrap()
}

fn fed_scenario() -> LifecycleScenario {
    use ace::svcgraph::lifecycle::{LifecycleOp, ScenarioStep};
    use ace::util::secs;
    LifecycleScenario {
        steps: vec![
            ScenarioStep { at: secs(0.0), op: LifecycleOp::Deploy(fed_topo(3, 1)) },
            ScenarioStep { at: secs(4.0), op: LifecycleOp::Update(fed_topo(6, 2)) },
            ScenarioStep { at: secs(9.0), op: LifecycleOp::Update(fed_topo(2, 3)) },
        ],
        duration: secs(14.0),
        network: None,
        faults: None,
    }
}

fn fed_cfg() -> FedConfig {
    FedConfig {
        rounds: 50,     // capped by the scenario horizon, not the count
        step_ms: 200.0, // ~0.8 s rounds, so ops land mid-training
        ..Default::default()
    }
}

#[test]
fn fedtrain_scales_trainers_up_and_down_mid_run() {
    let (m, report) = run_fedtrain_scenario(fed_cfg(), &fed_scenario()).unwrap();
    assert!(m.rounds.len() >= 5, "only {} rounds completed", m.rounds.len());
    // scale-out was live: some round averaged >= 5 trainer updates
    let max_trainers = m.rounds.iter().map(|r| r.trainers).max().unwrap();
    assert!(max_trainers >= 5, "scale-out never took effect: max {max_trainers}");
    // scale-in was live: the final rounds run with <= 3 trainers
    let last = m.rounds.last().unwrap();
    assert!(last.trainers <= 3, "scale-in never took effect: {}", last.trainers);
    // learning still works across the churn
    assert!(m.final_accuracy > 0.6, "final acc {:.3}", m.final_accuracy);
    assert!(m.wan_bytes > 0);
    // id-stable instances survive scaling: scale 3→6 adds 3 instances
    // without restarting the 3 kept ones (3 trainers + 1 coordinator
    // at deploy, then 3 more trainers)
    assert!(report.spawned >= 7, "spawned {}", report.spawned);
    assert!(
        report.events.iter().any(|(_, e)| e.contains("update 'fedtrain' v2: +3 -0 ~0")),
        "scale-out must diff as pure adds (id-stable multiset diff)"
    );
    assert!(
        report.events.iter().any(|(_, e)| e.contains("update 'fedtrain' v3: +0 -4 ~0")),
        "scale-in must diff as pure removes"
    );

    // determinism golden
    let (m2, report2) = run_fedtrain_scenario(fed_cfg(), &fed_scenario()).unwrap();
    assert_eq!(report.hash(), report2.hash());
    assert_eq!(m.final_accuracy.to_bits(), m2.final_accuracy.to_bits());
    assert_eq!(m.rounds.len(), m2.rounds.len());
}

/// Chaos cycle for the SECOND workload: EC-1's trainer node crashes
/// mid-training (twice — the second fail-node must be a no-op), the
/// monitor shields it, training continues on the survivors, the node
/// rejoins and the trainer set rebalances — all under 5% seeded loss
/// and 2% duplication on every message.
fn fed_chaos_scenario() -> LifecycleScenario {
    use ace::svcgraph::lifecycle::{LifecycleOp, ScenarioStep};
    use ace::util::{secs, AceId};
    let node = AceId::parse("infra-fed/ec-1/minipc");
    LifecycleScenario {
        steps: vec![
            ScenarioStep { at: secs(0.0), op: LifecycleOp::Deploy(fed_topo(3, 1)) },
            ScenarioStep { at: secs(5.0), op: LifecycleOp::FailNode(node.clone()) },
            // by now the sweep has shielded it: this must be a no-op
            ScenarioStep { at: secs(12.0), op: LifecycleOp::FailNode(node.clone()) },
            ScenarioStep { at: secs(16.0), op: LifecycleOp::RejoinNode(node) },
        ],
        duration: secs(26.0),
        network: None,
        faults: Some(FaultSpec { seed: 11, loss: 0.05, dup: 0.02 }),
    }
}

#[test]
fn fedtrain_survives_fail_rejoin_rebalance_under_loss() {
    let (m, r) = run_fedtrain_scenario(fed_cfg(), &fed_chaos_scenario()).unwrap();

    // chaos bit, and training still made progress across it
    assert!(r.msgs_lost > 0, "5% loss dropped nothing");
    assert!(m.rounds.len() >= 5, "only {} rounds completed", m.rounds.len());
    assert!(m.final_accuracy > 0.5, "final acc {:.3}", m.final_accuracy);

    // fail → shield → rejoin → rebalance, in the audit trail
    assert!(
        r.shielded.iter().any(|n| n.ends_with("ec-1/minipc")),
        "trainer node not shielded: {:?}",
        r.shielded
    );
    assert!(
        r.events
            .iter()
            .any(|(_, e)| e.contains("already shielded, no-op")),
        "second fail-node on a shielded node must be an audited no-op"
    );
    assert_eq!(
        r.shielded.iter().filter(|n| n.ends_with("ec-1/minipc")).count(),
        1,
        "the idempotent fail-node must not shield twice"
    );
    assert!(
        r.events
            .iter()
            .any(|(_, e)| e.contains("rejoin: node") && e.contains("ec-1/minipc")),
        "rejoin missing from the audit trail"
    );
    assert!(
        r.events
            .iter()
            .any(|(_, e)| e.contains("rejoin/rebalance 'fedtrain'")),
        "rejoin must re-place the trainers"
    );
    assert!(!r.convergence_us.is_empty(), "no fault episode converged");

    // determinism golden: the whole chaos run replays bit-identically
    let (m2, r2) = run_fedtrain_scenario(fed_cfg(), &fed_chaos_scenario()).unwrap();
    assert_eq!(r.hash(), r2.hash());
    assert_eq!(m.final_accuracy.to_bits(), m2.final_accuracy.to_bits());
    assert_eq!(r.events, r2.events);
}
