//! DES engine overhead (ablation): how many events/second the virtual
//! scheduler sustains — the budget within which every Figure 5 cell's
//! event churn (sampling, transfers, batch services) must fit.
//!
//! Run: `cargo bench --bench des_engine`

use ace::des::Scheduler;
use ace::util::prng::Stream;
use std::time::Instant;

fn churn(events: u64, chain: bool) -> f64 {
    let mut sched: Scheduler<u64> = Scheduler::new();
    let mut world = 0u64;
    if chain {
        // self-scheduling chain (the sampling-tick pattern)
        fn tick(sc: &mut Scheduler<u64>, w: &mut u64) {
            *w += 1;
            sc.after(10, tick);
        }
        sched.after(1, tick);
        let t0 = Instant::now();
        sched.run(&mut world, events);
        let dt = t0.elapsed().as_secs_f64();
        events as f64 / dt
    } else {
        // pre-seeded random heap (the transfer-completion pattern)
        let mut s = Stream::new(7);
        for _ in 0..events {
            let at = s.next_range(0, 1_000_000_000) as u64;
            sched.at(at, |_, w: &mut u64| *w += 1);
        }
        let t0 = Instant::now();
        sched.run(&mut world, events + 1);
        let dt = t0.elapsed().as_secs_f64();
        events as f64 / dt
    }
}

fn main() {
    println!("# DES engine throughput\n");
    println!("| pattern | events | events/s |");
    println!("|---|---|---|");
    for &n in &[100_000u64, 1_000_000] {
        let r = churn(n, true);
        println!("| chained ticks | {n} | {r:.0} |");
        let r = churn(n, false);
        println!("| random heap | {n} | {r:.0} |");
    }
    // a representative Figure-5 cell at the highest load runs ~1e5-1e6
    // events; anything above ~1e6 events/s keeps the DES negligible
    // next to real XLA inference.
}
