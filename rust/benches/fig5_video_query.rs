//! Figure 5 reproduction — the paper's headline evaluation.
//!
//! Sweeps paradigm {CI, EI, ACE, ACE+} x system load (OD sampling
//! interval 0.5 -> 0.1 s) x WAN one-way delay {0, 50 ms} on the
//! simulated §5.1.1 testbed with REAL XLA inference for every crop,
//! and prints the three metric tables (F1 / BWC / EIL).
//!
//! Run: `cargo bench --bench fig5_video_query`
//! Env:
//!   ACE_FIG5_FAST=1    — 3 load points, 15 s virtual duration
//!   ACE_FIG5_SECONDS=N — virtual duration override (default 30)
//!
//! Results land in stdout + artifacts/results_fig5.{md,csv}.

use ace::app::videoquery::{run_cell, CellConfig, Compute, InferCache, Paradigm, ServiceTimes};
use ace::metrics;
use ace::runtime::{artifacts_dir, Engine, ModelBank};
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("ACE_FIG5_FAST").is_ok();
    let duration: f64 = std::env::var("ACE_FIG5_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { 15.0 } else { 30.0 });
    let intervals: Vec<f64> = if fast {
        vec![0.5, 0.2, 0.1]
    } else {
        vec![0.5, 0.33, 0.2, 0.14, 0.1]
    };
    let delays = [0.0f64, 50.0];
    let paradigms = [Paradigm::Ci, Paradigm::Ei, Paradigm::AceBp, Paradigm::AceAp];

    eprintln!("[fig5] loading artifacts + calibrating PJRT executables...");
    let t0 = Instant::now();
    let engine = Engine::cpu()?;
    let dir = artifacts_dir()?;
    let mut bank = ModelBank::load(&engine, &dir)?;
    bank.calibrate(3)?;
    eprintln!(
        "[fig5] calibrated in {:.1}s  (eoc b1 {:.2} ms, coc b1 {:.2} ms measured)",
        t0.elapsed().as_secs_f64(),
        bank.eoc.service_time(1) * 1e3,
        bank.coc.service_time(1) * 1e3,
    );
    let svc = ServiceTimes::calibrated_to_paper(&bank);
    eprintln!(
        "[fig5] DES service times scaled to paper §5.2: eoc b1 {:.1} ms, coc b1 {:.1} ms",
        svc.eoc[&1] * 1e3,
        svc.coc[&1] * 1e3
    );

    let bank = Rc::new(bank);
    let cache = Rc::new(RefCell::new(InferCache::new()));
    let mut cells = Vec::new();
    for &delay in &delays {
        for &interval in &intervals {
            for &paradigm in &paradigms {
                let cfg = CellConfig {
                    paradigm,
                    interval_s: interval,
                    wan_delay_ms: delay,
                    duration_s: duration,
                    seed: 1,
                    ..Default::default()
                };
                let t = Instant::now();
                let compute = Compute::Real { bank: bank.clone(), cache: cache.clone() };
                let mut m = run_cell(cfg, svc.clone(), compute)?;
                let eil_ms = m.eil_ms();
                eprintln!(
                    "[fig5] {:>4} interval={:.2}s delay={:>2}ms: crops={} F1={:.3} BWC={:.2}MB EIL={:.1}ms  ({:.1}s wall)",
                    paradigm.name(),
                    interval,
                    delay,
                    m.crops,
                    m.f1.f1(),
                    m.bwc_mb(),
                    eil_ms,
                    t.elapsed().as_secs_f64()
                );
                cells.push(m);
            }
        }
    }

    let tables = metrics::figure5_tables(&mut cells);
    let csv = metrics::figure5_csv(&mut cells);
    println!("\n# Figure 5 reproduction (virtual duration {duration} s per cell)\n{tables}");
    std::fs::write(dir.join("results_fig5.md"), format!("# Figure 5\n{tables}"))?;
    std::fs::write(dir.join("results_fig5.csv"), &csv)?;
    eprintln!(
        "[fig5] wrote {} cells -> artifacts/results_fig5.md / .csv  (cache: {} eoc execs, {} coc execs)",
        cells.len(),
        cache.borrow().eoc_execs,
        cache.borrow().coc_execs
    );
    Ok(())
}
