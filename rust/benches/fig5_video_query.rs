//! Figure 5 reproduction — the paper's headline evaluation.
//!
//! Sweeps paradigm {CI, EI, ACE, ACE+} x system load (OD sampling
//! interval 0.5 -> 0.1 s) x WAN one-way delay {0, 50 ms} on the
//! simulated §5.1.1 testbed with REAL XLA inference for every crop,
//! and prints the three metric tables (F1 / BWC / EIL).
//!
//! Cells are independent DES worlds and run on the parallel sweep
//! engine (`run_sweep`): wall-clock is max-of-cells, results are
//! bit-identical to the serial order.
//!
//! Run: `cargo bench --bench fig5_video_query`
//! Env:
//!   ACE_FIG5_FAST=1    — 3 load points, 15 s virtual duration
//!   ACE_FIG5_SECONDS=N — virtual duration override (default 30)
//!   ACE_FIG5_WORKERS=N — worker threads (default: all cores)
//!
//! Results land in stdout + artifacts/results_fig5.{md,csv}.

use ace::app::videoquery::{fig5_grid, run_sweep, Compute, InferCache, ServiceTimes};
use ace::metrics;
use ace::runtime::{artifacts_dir, Engine, ModelBank};
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("ACE_FIG5_FAST").is_ok();
    let duration: f64 = std::env::var("ACE_FIG5_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { 15.0 } else { 30.0 });
    let workers: usize = std::env::var("ACE_FIG5_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(ace::sweep::default_workers);
    let intervals: Vec<f64> = if fast {
        vec![0.5, 0.2, 0.1]
    } else {
        vec![0.5, 0.33, 0.2, 0.14, 0.1]
    };

    eprintln!("[fig5] loading artifacts + calibrating PJRT executables...");
    let t0 = Instant::now();
    let engine = Engine::cpu()?;
    let dir = artifacts_dir()?;
    let mut bank = ModelBank::load(&engine, &dir)?;
    bank.calibrate(3)?;
    eprintln!(
        "[fig5] calibrated in {:.1}s  (eoc b1 {:.2} ms, coc b1 {:.2} ms measured)",
        t0.elapsed().as_secs_f64(),
        bank.eoc.service_time(1) * 1e3,
        bank.coc.service_time(1) * 1e3,
    );
    let svc = ServiceTimes::calibrated_to_paper(&bank);
    eprintln!(
        "[fig5] DES service times scaled to paper §5.2: eoc b1 {:.1} ms, coc b1 {:.1} ms",
        svc.eoc[&1] * 1e3,
        svc.coc[&1] * 1e3
    );

    let bank = Arc::new(bank);
    let cfgs = fig5_grid(&intervals, &[0.0, 50.0], duration, 1);
    let n = cfgs.len();
    eprintln!("[fig5] running {n} cells on {workers} worker(s)...");
    let t0 = Instant::now();
    let cells = run_sweep(cfgs, workers, || {
        // one InferCache per worker: identical crops recur across that
        // worker's cells, and workers never contend on a shared lock
        let cache = Arc::new(Mutex::new(InferCache::new()));
        (svc.clone(), Compute::Real { bank: bank.clone(), cache })
    })?;
    let wall = t0.elapsed().as_secs_f64();
    for m in &cells {
        eprintln!(
            "[fig5] {:>4} interval={:.2}s delay={:>2}ms: crops={} F1={:.3} BWC={:.2}MB EIL={:.1}ms",
            m.paradigm,
            m.interval_s,
            m.wan_delay_ms,
            m.crops,
            m.f1.f1(),
            m.bwc_mb(),
            m.eil_ms(),
        );
    }
    eprintln!("[fig5] {n} cells in {wall:.1}s wall ({:.1}s/cell)", wall / n as f64);

    let tables = metrics::figure5_tables(&cells);
    let csv = metrics::figure5_csv(&cells);
    println!("\n# Figure 5 reproduction (virtual duration {duration} s per cell)\n{tables}");
    std::fs::write(dir.join("results_fig5.md"), format!("# Figure 5\n{tables}"))?;
    std::fs::write(dir.join("results_fig5.csv"), &csv)?;
    eprintln!("[fig5] wrote {n} cells -> artifacts/results_fig5.md / .csv");
    Ok(())
}
