//! §5.2 inline numbers: COC vs EOC service-time asymmetry.
//!
//! The paper reports "the inference time of COC is about 32.3 ms on CC,
//! and that of EOC on edge node is above 44 ms". This bench measures
//! the real PJRT service times of both compiled classifiers across the
//! exported batch sizes, and prints both the raw numbers and the
//! paper-scaled DES operating point (ServiceTimes::calibrated_to_paper).
//!
//! Run: `cargo bench --bench inference_latency`

use ace::app::videoquery::ServiceTimes;
use ace::runtime::{artifacts_dir, Engine, ModelBank};

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let dir = artifacts_dir()?;
    let mut bank = ModelBank::load(&engine, &dir)?;
    eprintln!("[inference] calibrating (10 reps per batch size)...");
    bank.calibrate(10)?;

    println!("# Classifier service times (measured on PJRT CPU)\n");
    println!("| model | params | batch | total ms | ms/crop | crops/s |");
    println!("|---|---|---|---|---|---|");
    for (name, clf) in [("eoc", &bank.eoc), ("coc", &bank.coc)] {
        let params = bank.manifest.models[name].params;
        for &b in &clf.batch_sizes {
            let t = clf.service_time(b);
            println!(
                "| {name} | {params} | {b} | {:.3} | {:.3} | {:.0} |",
                t * 1e3,
                t * 1e3 / b as f64,
                b as f64 / t
            );
        }
    }

    let svc = ServiceTimes::calibrated_to_paper(&bank);
    println!("\n# DES operating point (scaled to paper §5.2: coc b1 = 32.3 ms, eoc b1 = 44 ms)\n");
    println!("| model | batch | total ms | ms/crop |");
    println!("|---|---|---|---|");
    let mut keys: Vec<_> = svc.eoc.keys().copied().collect();
    keys.sort_unstable();
    for (name, table) in [("eoc@miniPC", &svc.eoc), ("coc@CC", &svc.coc)] {
        for &b in &keys {
            let t = table[&b];
            println!("| {name} | {b} | {:.1} | {:.2} |", t * 1e3, t * 1e3 / b as f64);
        }
    }

    // the paper's qualitative claim: per-crop EOC on the edge is slower
    // than per-crop COC on the cloud
    let ratio = svc.eoc[&1] / svc.coc[&1];
    println!(
        "\nEOC-edge / COC-cloud per-crop ratio at b=1: {ratio:.2} (paper: 44/32.3 = 1.36)"
    );
    Ok(())
}
