//! Hop-charged routing throughput: the degenerate flat fabric (one
//! shared LAN send per cross-node delivery) vs the PR-5 per-node link
//! graph (src NIC → LAN → dst NIC per delivery).
//!
//! Shares its measurement body with `ace bench` (`benchkit::
//! netfabric_hops`), so a bench number and a CI number are never two
//! different experiments.
//!
//! Run: `cargo bench --bench netfabric_hops`

use ace::benchkit;

fn main() {
    println!("# NetFabric hop-charged routing (flat vs per-node)\n");
    println!("| pubs | sinks | deliveries | flat pubs/s | hop-charged pubs/s | overhead |");
    println!("|---|---|---|---|---|---|");
    for (pubs, sinks) in [(5_000usize, 16usize), (20_000, 64), (50_000, 128)] {
        let h = benchkit::netfabric_hops(pubs, sinks);
        println!(
            "| {} | {} | {} | {:.0} | {:.0} | {:.2}x |",
            h.pubs,
            h.sinks,
            h.deliveries,
            h.flat_pubs_per_s,
            h.hop_pubs_per_s,
            h.flat_pubs_per_s / h.hop_pubs_per_s.max(1.0)
        );
    }
    println!("\n(Each cross-node delivery on the per-node fabric pays three FIFO");
    println!("legs instead of one; the overhead bounds what NIC modelling costs");
    println!("the routing hot path.)");
}
