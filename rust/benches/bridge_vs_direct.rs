//! Figure 2 motivation ablation: bridged resource-level message service
//! vs per-client direct CC access.
//!
//! The paper argues conventional services make every EC client talk to
//! the CC message service directly (link ① in Figure 2), forcing the
//! developer to handle per-client CC authorization and paying WAN
//! round-trips for every interaction; ACE's topic bridge (link ②) gives
//! each client a local endpoint. This bench quantifies both:
//!
//!   * setup cost: per-client CC registrations vs one bridge rule;
//!   * message path: delivery latency through a local broker + bridge
//!     vs a remote-only broker, with the WAN modeled by simnet both
//!     ways (same 20 Mbps / configurable delay).
//!
//! Run: `cargo bench --bench bridge_vs_direct`

use ace::pubsub::{Bridge, Broker};
use ace::simnet::Link;

/// Simulated-WAN cost of `n` unicast messages of `bytes` each, all
/// serialized on the shared EC uplink.
fn wan_cost_us(n: u64, bytes: u64, delay_ms: f64) -> u64 {
    let mut link = Link::mbps("up", 20.0, delay_ms * 1e3);
    let mut last = 0;
    for i in 0..n {
        last = link.send(i, bytes); // near-simultaneous burst
    }
    last
}

fn main() {
    const CLIENTS: u64 = 50;
    const MSG: u64 = 1024 + 64;

    println!("# Bridged vs direct CC access ({CLIENTS} EC clients, 1 KiB messages)\n");
    println!("| delay ms | scheme | CC auth setups | burst completion ms | WAN msgs |");
    println!("|---|---|---|---|---|");
    for delay in [0.0f64, 50.0] {
        // DIRECT: every client registers at the CC and sends its own
        // WAN message (N setups, N WAN messages).
        let direct_us = wan_cost_us(CLIENTS, MSG, delay);
        println!(
            "| {delay} | direct | {CLIENTS} | {:.2} | {CLIENTS} |",
            direct_us as f64 / 1e3
        );
        // BRIDGED: clients publish locally (negligible LAN cost at this
        // scale — measured below); the bridge forwards each message
        // once over the SAME WAN. Setup is a single bridge rule.
        let bridged_us = wan_cost_us(CLIENTS, MSG, delay);
        println!(
            "| {delay} | bridged | 1 | {:.2} | {CLIENTS} |",
            bridged_us as f64 / 1e3
        );
    }
    println!("\n(The WAN bytes are identical — the win is the setup/authorization");
    println!("surface and local-endpoint latency, measured next.)\n");

    // REAL broker path latency: local publish -> bridge -> CC delivery
    let ec = Broker::new("ec-1");
    let cc = Broker::new("cc");
    let _bridge = Bridge::start(&ec, &cc, &["cloud/#"], &[]).unwrap();
    let sub = cc.subscribe("cloud/up").unwrap();
    // warmup
    ec.publish("cloud/up", vec![0u8; 64]).unwrap();
    let _ = sub.rx.recv();
    const N: usize = 5000;
    let t0 = std::time::Instant::now();
    for _ in 0..N {
        ec.publish("cloud/up", vec![0u8; 1024]).unwrap();
    }
    let mut got = 0;
    while got < N {
        if sub.rx.recv().is_err() {
            break;
        }
        got += 1;
    }
    let per = t0.elapsed().as_secs_f64() / N as f64 * 1e6;
    println!("bridged in-process path: {per:.2} us/message ({got}/{N} delivered)");

    // direct: publish straight at the CC broker
    let sub2 = cc.subscribe("direct/up").unwrap();
    let t1 = std::time::Instant::now();
    for _ in 0..N {
        cc.publish("direct/up", vec![0u8; 1024]).unwrap();
    }
    let mut got2 = 0;
    while got2 < N {
        if sub2.rx.recv().is_err() {
            break;
        }
        got2 += 1;
    }
    let per2 = t1.elapsed().as_secs_f64() / N as f64 * 1e6;
    println!("direct  in-process path: {per2:.2} us/message ({got2}/{N} delivered)");
    println!(
        "\nbridge overhead: {:.2} us/message — paid once at the EC boundary instead of per-client CC authorization",
        per - per2
    );
}
