//! Zero-allocation event hot path (PR 3 ablation): typed by-value DES
//! events vs the boxed closure lane, the calendar-queue scheduler vs
//! the binary heap under a timer-dense heartbeat storm (PR 6), trie
//! match collection with vs without a reused scratch buffer, and the
//! end-to-end 10k-component fabric storm riding the allocation-free
//! `Fabric::route`.
//!
//! The measurement bodies live in `ace::benchkit` so `ace bench
//! --json` (the CI `BENCH_*.json` emitter) runs the same code.
//!
//! Run: `cargo bench --bench des_throughput`

use ace::benchkit;

fn main() {
    println!("# DES event hot path: typed lane vs boxed closure lane\n");
    println!("| pattern | events | boxed ev/s | typed ev/s | speedup |");
    println!("|---|---|---|---|---|");
    for &n in &[100_000u64, 1_000_000] {
        let d = benchkit::des_throughput(n);
        println!(
            "| chained ticks | {n} | {:.0} | {:.0} | {:.2}x |",
            d.boxed_chain_eps,
            d.typed_chain_eps,
            d.typed_chain_eps / d.boxed_chain_eps
        );
        println!(
            "| random heap | {n} | {:.0} | {:.0} | {:.2}x |",
            d.boxed_heap_eps,
            d.typed_heap_eps,
            d.typed_heap_eps / d.boxed_heap_eps
        );
    }

    println!("\n# DES timer storm: calendar queue (wheel) vs binary heap\n");
    println!("| timers | events | heap ev/s | wheel ev/s | speedup |");
    println!("|---|---|---|---|---|");
    for &timers in &[1_000usize, 10_000] {
        let t = benchkit::des_timer_storm(timers, 1_000_000);
        println!(
            "| {} | {} | {:.0} | {:.0} | {:.2}x |",
            t.timers,
            t.events,
            t.heap_events_per_sec,
            t.wheel_events_per_sec,
            t.wheel_events_per_sec / t.heap_events_per_sec
        );
    }

    println!("\n# Route match collection: fresh Vec vs reused scratch\n");
    println!("| subs | pubs | alloc pubs/s | scratch pubs/s | speedup |");
    println!("|---|---|---|---|---|");
    for n_subs in [1_000usize, 10_000] {
        let r = benchkit::route_scratch(n_subs, 20_000);
        println!(
            "| {} | {} | {:.0} | {:.0} | {:.2}x |",
            r.subs,
            r.pubs,
            r.alloc_pubs_per_s,
            r.scratch_pubs_per_s,
            r.scratch_pubs_per_s / r.alloc_pubs_per_s
        );
    }

    let st = benchkit::fabric_storm(10_000, 2_000);
    println!(
        "\nfabric storm (zero-alloc publish path): {} comps, {} publishes -> \
         {} deliveries, {} DES events, {:.0} pubs/s",
        st.components, st.publishes, st.deliveries, st.des_events, st.pubs_per_s
    );
    println!("\nOK: typed/boxed and alloc/scratch paths agree at every scale");
}
