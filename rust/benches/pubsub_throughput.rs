//! Resource-level message service microbenchmark (ablation).
//!
//! Measures broker publish->deliver throughput and latency across
//! fanout (subscribers per topic) and payload-size sweeps — the
//! envelope within which all ACE control traffic (deployment
//! instructions, status reports, in-app control messages) operates.
//!
//! Run: `cargo bench --bench pubsub_throughput`

use ace::pubsub::topic::{self, SymbolTable, TopicTrie};
use ace::pubsub::Broker;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn bench_case(fanout: usize, payload: usize, msgs: u64) -> (f64, f64) {
    let broker = Broker::new("bench");
    let done = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for _ in 0..fanout {
        let sub = broker.subscribe("bench/t").unwrap();
        let done = done.clone();
        handles.push(std::thread::spawn(move || {
            let mut got = 0u64;
            while got < msgs {
                if sub.rx.recv().is_err() {
                    break;
                }
                got += 1;
            }
            done.fetch_add(got, Ordering::Relaxed);
        }));
    }
    let body = vec![0u8; payload];
    let t0 = Instant::now();
    for _ in 0..msgs {
        broker.publish("bench/t", body.clone()).unwrap();
    }
    for h in handles {
        let _ = h.join();
    }
    let dt = t0.elapsed().as_secs_f64();
    let delivered = done.load(Ordering::Relaxed);
    (delivered as f64 / dt, dt / msgs as f64 * 1e6)
}

fn main() {
    println!("# Message service throughput (publish -> all subscribers)\n");
    println!("| fanout | payload B | deliveries/s | us/publish |");
    println!("|---|---|---|---|");
    for fanout in [1usize, 4, 16] {
        for payload in [64usize, 1024, 16 * 1024] {
            let msgs = 20_000u64 / fanout as u64;
            let (rate, us) = bench_case(fanout, payload, msgs);
            println!("| {fanout} | {payload} | {rate:.0} | {us:.2} |");
        }
    }
    // retained-message replay cost: wide filter (replays everything)
    let broker = Broker::new("retained");
    for i in 0..1000 {
        broker
            .publish_retained(&format!("cfg/{i}"), vec![0u8; 128])
            .unwrap();
    }
    let t0 = Instant::now();
    let sub = broker.subscribe("cfg/#").unwrap();
    let mut got = 0;
    while sub.rx.try_recv().is_ok() {
        got += 1;
    }
    println!(
        "\nretained replay (wide cfg/#): {got} messages in {:.2} ms on subscribe",
        t0.elapsed().as_secs_f64() * 1e3
    );
    assert_eq!(got, 1000);
    // narrow filter: the name-keyed retained trie walks ONE path
    // instead of scanning all 1000 retained topics per subscribe (the
    // pre-PR-3 full-HashMap scan)
    const NARROW: u64 = 10_000;
    let t0 = Instant::now();
    let mut got = 0u64;
    for i in 0..NARROW {
        let sub = broker.subscribe(&format!("cfg/{}", i % 1000)).unwrap();
        while sub.rx.try_recv().is_ok() {
            got += 1;
        }
        broker.unsubscribe(sub.id);
    }
    let per_sub_us = t0.elapsed().as_secs_f64() / NARROW as f64 * 1e6;
    assert_eq!(got, NARROW, "each narrow subscribe replays exactly one message");
    println!(
        "retained replay (narrow, 1000 retained topics): {per_sub_us:.2} us/subscribe \
         (trie path walk, not a full retained scan)"
    );

    // --- dead-subscriber pruning: one O(subs) retain pass ---
    // 4096 subscribers whose receivers are gone; the first publish must
    // prune ALL of them (HashSet membership, not a per-dead linear
    // scan), leaving later publishes on the fast path.
    const DEAD: usize = 4096;
    let broker = Broker::new("prune");
    let live = broker.subscribe("t/x").unwrap();
    for _ in 0..DEAD {
        let s = broker.subscribe("t/x").unwrap();
        drop(s.rx);
    }
    let t0 = Instant::now();
    broker.publish("t/x", vec![0u8; 64]).unwrap();
    let prune_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(
        broker.stats().subscriptions,
        1,
        "all dead subscriptions must be pruned by one publish"
    );
    const AFTER: u64 = 50_000;
    let t0 = Instant::now();
    for _ in 0..AFTER {
        broker.publish("t/x", vec![0u8; 64]).unwrap();
    }
    let per_pub_us = t0.elapsed().as_secs_f64() / AFTER as f64 * 1e6;
    while live.rx.try_recv().is_ok() {}
    println!(
        "\ndead-sub pruning: {DEAD} dead subs pruned in {prune_ms:.2} ms; \
         steady-state publish {per_pub_us:.2} us"
    );
    // throughput floor (generous: even a laptop under load clears
    // 10k publishes/s to a single subscriber once the subs list is
    // clean; the pre-fix quadratic prune alone blew past this budget)
    assert!(
        per_pub_us < 100.0,
        "publish too slow after pruning: {per_pub_us:.2} us"
    );

    // --- Arc payload: fanout shares one buffer ---
    // publishing a 1 MiB payload to 32 subscribers must account 32 MiB
    // delivered while the publish itself stays cheap (refcount bumps,
    // not 32 memcpys).
    let broker = Broker::new("arc");
    let subs: Vec<_> = (0..32).map(|_| broker.subscribe("big/x").unwrap()).collect();
    let big = vec![0u8; 1 << 20];
    let t0 = Instant::now();
    for _ in 0..64 {
        broker.publish("big/x", big.clone()).unwrap();
    }
    let fan_ms = t0.elapsed().as_secs_f64() * 1e3;
    let st = broker.stats();
    assert_eq!(st.deliver_count, 32 * 64);
    assert_eq!(st.deliver_bytes, 32 * 64 * (1 << 20));
    drop(subs);
    println!("arc fanout: 64 x 1 MiB x 32 subs in {fan_ms:.2} ms");

    // --- trie-indexed routing vs the old linear scan ---
    // 10k subscriptions across 500 topics (plus wildcard filters); a
    // publish to one topic must route in O(topic depth), not O(subs).
    // The linear reference below is exactly what `publish` did before
    // the TopicTrie index.
    const SUBS: usize = 10_000;
    const TOPICS: usize = 500;
    let filters: Vec<String> = (0..SUBS)
        .map(|i| match i % 10 {
            0 => format!("sensor/room{}/#", i % TOPICS),
            1 => format!("sensor/+/t{}", i % 50),
            _ => format!("sensor/room{}/t{}", i % TOPICS, i % 50),
        })
        .collect();
    let mut table = SymbolTable::new();
    let mut trie = TopicTrie::new();
    for (i, f) in filters.iter().enumerate() {
        trie.insert(&mut table, f, i);
    }
    const PUBS: u64 = 20_000;
    let name = |i: u64| format!("sensor/room{}/t{}", i % TOPICS as u64, i % 50);
    let t0 = Instant::now();
    let mut linear_hits = 0usize;
    for i in 0..PUBS {
        let n = name(i);
        linear_hits += filters.iter().filter(|f| topic::matches(f.as_str(), &n)).count();
    }
    let linear_us = t0.elapsed().as_secs_f64() / PUBS as f64 * 1e6;
    let t0 = Instant::now();
    let mut trie_hits = 0usize;
    for i in 0..PUBS {
        trie_hits += trie.collect_matches(&table, &name(i)).len();
    }
    let trie_us = t0.elapsed().as_secs_f64() / PUBS as f64 * 1e6;
    assert_eq!(trie_hits, linear_hits, "trie must agree with the linear scan");
    println!(
        "\ntrie vs linear @ {SUBS} subs: linear {linear_us:.2} us/publish, \
         trie {trie_us:.2} us/publish ({:.1}x)",
        linear_us / trie_us
    );
    // the broker itself routes through the same trie: a publish into a
    // 10k-subscription broker must stay far under the linear scan cost
    let broker = Broker::new("trie");
    let mut keep = Vec::new();
    for f in &filters {
        keep.push(broker.subscribe(f).unwrap());
    }
    let t0 = Instant::now();
    for i in 0..PUBS {
        broker.publish(&name(i), b"x".to_vec()).unwrap();
    }
    let broker_us = t0.elapsed().as_secs_f64() / PUBS as f64 * 1e6;
    println!("broker publish @ {SUBS} subs: {broker_us:.2} us/publish (trie-indexed)");
    drop(keep);

    println!("\nOK: pruning + fanout + trie assertions passed");
}
