//! Resource-level message service microbenchmark (ablation).
//!
//! Measures broker publish->deliver throughput and latency across
//! fanout (subscribers per topic) and payload-size sweeps — the
//! envelope within which all ACE control traffic (deployment
//! instructions, status reports, in-app control messages) operates.
//!
//! Run: `cargo bench --bench pubsub_throughput`

use ace::pubsub::Broker;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn bench_case(fanout: usize, payload: usize, msgs: u64) -> (f64, f64) {
    let broker = Broker::new("bench");
    let done = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for _ in 0..fanout {
        let sub = broker.subscribe("bench/t").unwrap();
        let done = done.clone();
        handles.push(std::thread::spawn(move || {
            let mut got = 0u64;
            while got < msgs {
                if sub.rx.recv().is_err() {
                    break;
                }
                got += 1;
            }
            done.fetch_add(got, Ordering::Relaxed);
        }));
    }
    let body = vec![0u8; payload];
    let t0 = Instant::now();
    for _ in 0..msgs {
        broker.publish("bench/t", body.clone()).unwrap();
    }
    for h in handles {
        let _ = h.join();
    }
    let dt = t0.elapsed().as_secs_f64();
    let delivered = done.load(Ordering::Relaxed);
    (delivered as f64 / dt, dt / msgs as f64 * 1e6)
}

fn main() {
    println!("# Message service throughput (publish -> all subscribers)\n");
    println!("| fanout | payload B | deliveries/s | us/publish |");
    println!("|---|---|---|---|");
    for fanout in [1usize, 4, 16] {
        for payload in [64usize, 1024, 16 * 1024] {
            let msgs = 20_000u64 / fanout as u64;
            let (rate, us) = bench_case(fanout, payload, msgs);
            println!("| {fanout} | {payload} | {rate:.0} | {us:.2} |");
        }
    }
    // retained-message replay cost
    let broker = Broker::new("retained");
    for i in 0..1000 {
        broker
            .publish_retained(&format!("cfg/{i}"), vec![0u8; 128])
            .unwrap();
    }
    let t0 = Instant::now();
    let sub = broker.subscribe("cfg/#").unwrap();
    let mut got = 0;
    while sub.rx.try_recv().is_ok() {
        got += 1;
    }
    println!(
        "\nretained replay: {got} messages in {:.2} ms on subscribe",
        t0.elapsed().as_secs_f64() * 1e3
    );
}
