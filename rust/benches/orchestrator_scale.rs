//! Deployment automation at scale (Figure 4 mechanism bench).
//!
//! §6.1 names agile orchestration as ACE's key scaling challenge. This
//! bench measures (a) orchestration latency (topology -> deployment
//! plan) and (b) instruction generation+parse cost, as components and
//! nodes grow — the regime where "prevents users from handling complex
//! component-infrastructure mapping" must stay cheap.
//!
//! Run: `cargo bench --bench orchestrator_scale`

use ace::infra::{InfraBuilder, NodeKind};
use ace::platform::orchestrator;
use ace::topology::Topology;
use ace::yamlite;
use std::collections::BTreeMap;
use std::time::Instant;

fn build_infra(ecs: usize, nodes_per_ec: usize) -> ace::infra::Infrastructure {
    let mut b = InfraBuilder::register("scale");
    for _ in 0..ecs {
        let ec = b.claim_ec();
        b.add_edge_node(&ec, "minipc", NodeKind::MiniPc, BTreeMap::new());
        for r in 0..nodes_per_ec.saturating_sub(1) {
            let mut labels = BTreeMap::new();
            labels.insert("camera".to_string(), "true".to_string());
            b.add_edge_node(&ec, &format!("rpi{r}"), NodeKind::RaspberryPi, labels);
        }
    }
    for c in 0..4 {
        b.add_cloud_node(&format!("srv{c}"), NodeKind::CloudServer, BTreeMap::new());
    }
    b.build()
}

fn build_topology(components: usize) -> Topology {
    let mut doc = String::from("app: scale\nversion: 1\ncomponents:\n");
    for i in 0..components {
        let loc = if i % 3 == 0 { "cloud" } else { "edge" };
        doc.push_str(&format!(
            "  - name: c{i}\n    location: {loc}\n    resources:\n      cpu: 50\n      mem: 16\n",
        ));
    }
    Topology::parse(&doc).unwrap()
}

fn main() {
    println!("# Orchestration latency vs scale\n");
    println!("| nodes | components | instances | orchestrate ms | instructions ms |");
    println!("|---|---|---|---|---|");
    for (ecs, npe, comps) in [
        (3, 4, 10),
        (10, 8, 50),
        (30, 8, 100),
        (50, 10, 200),
        (100, 10, 500),
    ] {
        let infra = build_infra(ecs, npe);
        let topo = build_topology(comps);
        let nodes = infra.all_nodes().count();
        let t0 = Instant::now();
        let plan = orchestrator::place(&topo, &infra).expect("place");
        let orch_ms = t0.elapsed().as_secs_f64() * 1e3;
        // instruction generation for every touched node (Figure 4 ②)
        let t1 = Instant::now();
        let mut rendered = 0usize;
        for (_node, instances) in plan.by_node() {
            let services: Vec<(String, String, String)> = instances
                .iter()
                .map(|i| (i.id.clone(), i.component.clone(), i.image.clone()))
                .collect();
            let doc = ace::infra::agent::compose_instruction("scale", &services);
            let parsed = yamlite::parse(&doc).unwrap();
            rendered += parsed.get("services").as_obj().map(|o| o.len()).unwrap_or(0);
        }
        let instr_ms = t1.elapsed().as_secs_f64() * 1e3;
        assert_eq!(rendered, plan.instances.len());
        println!(
            "| {nodes} | {comps} | {} | {orch_ms:.2} | {instr_ms:.2} |",
            plan.instances.len()
        );
    }

    // incremental update vs thorough redeploy at the largest scale
    let infra = build_infra(50, 10);
    let topo = build_topology(200);
    let plan = orchestrator::place(&topo, &infra).unwrap();
    let mut topo2 = topo.clone();
    topo2.version = 2;
    topo2.components[0].image = "changed:2".into();
    let t0 = Instant::now();
    let plan2 = orchestrator::place(&topo2, &infra).unwrap();
    let diff = ace::deploy::diff_plans(&plan, &plan2);
    let diff_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "\nincremental update (1 of 200 components changed): {} nodes touched of {}, {diff_ms:.2} ms",
        diff.touched_nodes().len(),
        plan2.nodes().len()
    );
}
