//! Routing-index microbenchmark: topic-trie vs linear-scan matching at
//! platform scale (10k components, wildcard-heavy filter tables), plus
//! an end-to-end publish storm through the trie-backed
//! `svcgraph::Fabric`.
//!
//! This is the scale the ROADMAP calls out: a linear scan per publish
//! is fine at 40 components and wrong at 10k. The trie routes in
//! O(topic depth); the linear reference below is exactly what
//! `Fabric::route` and `Broker::publish` did before the index. The
//! corpus generators and the storm body live in `ace::benchkit`
//! (shared with `benches/des_throughput.rs` and `ace bench`).
//!
//! Run: `cargo bench --bench fabric_routing`

use ace::benchkit::{self, make_filters, make_names};
use ace::pubsub::topic::{self, SymbolTable, TopicTrie};
use ace::util::prng::Stream;
use std::time::Instant;

fn bench_index(n_subs: usize, n_pubs: usize) {
    let groups = 64;
    let mut s = Stream::new(7);
    let filters = make_filters(n_subs, groups, &mut s);
    let names = make_names(n_pubs, groups, &mut s);

    let mut table = SymbolTable::new();
    let mut trie = TopicTrie::new();
    for (i, f) in filters.iter().enumerate() {
        trie.insert(&mut table, f, i);
    }

    // the pre-index router: scan every subscription per publish
    let t0 = Instant::now();
    let mut linear_hits = 0usize;
    for name in &names {
        linear_hits += filters.iter().filter(|f| topic::matches(f.as_str(), name)).count();
    }
    let linear_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut trie_hits = 0usize;
    for name in &names {
        trie_hits += trie.collect_matches(&table, name).len();
    }
    let trie_s = t0.elapsed().as_secs_f64();

    assert_eq!(trie_hits, linear_hits, "index must agree with the reference scan");
    println!(
        "| {n_subs} | {n_pubs} | {:.0} | {:.0} | {:.1}x |",
        n_pubs as f64 / linear_s,
        n_pubs as f64 / trie_s,
        linear_s / trie_s
    );
}

fn main() {
    println!("# Routing index: trie vs linear scan (wildcard-heavy tables)\n");
    println!("| subscriptions | publishes | linear pubs/s | trie pubs/s | speedup |");
    println!("|---|---|---|---|---|");
    for n_subs in [100usize, 1_000, 10_000] {
        bench_index(n_subs, 20_000);
    }
    println!();
    // end-to-end: 10k components subscribed on a 4-EC fabric, one
    // publisher per EC blasting through the trie-indexed, allocation-
    // free `route`
    let st = benchkit::fabric_storm(10_000, 2_000);
    println!(
        "fabric storm: {} comps, {} publishes -> {} deliveries, \
         {} DES events ({:.0} pubs/s)",
        st.components, st.publishes, st.deliveries, st.des_events, st.pubs_per_s
    );
    println!("\nOK: trie agrees with the linear reference at every scale");
}
