//! Routing-index microbenchmark: topic-trie vs linear-scan matching at
//! platform scale (10k components, wildcard-heavy filter tables), plus
//! an end-to-end publish storm through the trie-backed
//! `svcgraph::Fabric`.
//!
//! This is the scale the ROADMAP calls out: a linear scan per publish
//! is fine at 40 components and wrong at 10k. The trie routes in
//! O(topic depth); the linear reference below is exactly what
//! `Fabric::route` and `Broker::publish` did before the index.
//!
//! Run: `cargo bench --bench fabric_routing`

use ace::pubsub::topic::{self, TopicTrie};
use ace::simnet::{EdgeCloudNet, NetConfig};
use ace::svcgraph::{ClusterRef, Component, Ctx, GraphMsg, GraphRuntime, Site};
use ace::util::prng::Stream;
use std::cell::Cell;
use std::rc::Rc;
use std::time::Instant;

/// Wildcard-heavy filter table: ~60% exact, ~20% `+`, ~20% `#`,
/// spread over `groups` topic groups (tenants/apps).
fn make_filters(n: usize, groups: usize, s: &mut Stream) -> Vec<String> {
    (0..n)
        .map(|i| {
            let g = i % groups;
            let t = s.next_range(0, 50);
            match s.next_range(0, 10) {
                0 | 1 => format!("app/g{g}/#"),
                2 => format!("app/+/t{t}/data"),
                3 => format!("app/g{g}/+/data"),
                _ => format!("app/g{g}/t{t}/data"),
            }
        })
        .collect()
}

fn make_names(n: usize, groups: usize, s: &mut Stream) -> Vec<String> {
    (0..n)
        .map(|_| {
            let g = s.next_range(0, groups as i64);
            let t = s.next_range(0, 50);
            format!("app/g{g}/t{t}/data")
        })
        .collect()
}

fn bench_index(n_subs: usize, n_pubs: usize) {
    let groups = 64;
    let mut s = Stream::new(7);
    let filters = make_filters(n_subs, groups, &mut s);
    let names = make_names(n_pubs, groups, &mut s);

    let mut trie = TopicTrie::new();
    for (i, f) in filters.iter().enumerate() {
        trie.insert(f, i);
    }

    // the pre-index router: scan every subscription per publish
    let t0 = Instant::now();
    let mut linear_hits = 0usize;
    for name in &names {
        linear_hits += filters.iter().filter(|f| topic::matches(f.as_str(), name)).count();
    }
    let linear_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut trie_hits = 0usize;
    for name in &names {
        trie_hits += trie.collect_matches(name).len();
    }
    let trie_s = t0.elapsed().as_secs_f64();

    assert_eq!(trie_hits, linear_hits, "index must agree with the reference scan");
    println!(
        "| {n_subs} | {n_pubs} | {:.0} | {:.0} | {:.1}x |",
        n_pubs as f64 / linear_s,
        n_pubs as f64 / trie_s,
        linear_s / trie_s
    );
}

/// Sink component: counts deliveries.
struct Sink {
    filters: Vec<String>,
    hits: Rc<Cell<u64>>,
}

impl Component for Sink {
    fn subscriptions(&self) -> Vec<String> {
        self.filters.clone()
    }
    fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {
        self.hits.set(self.hits.get() + 1);
    }
}

/// Publisher component: one publish per timer tick until done.
struct Blaster {
    topics: Vec<String>,
    i: usize,
}

impl Component for Blaster {
    fn subscriptions(&self) -> Vec<String> {
        Vec::new()
    }
    fn on_start(&mut self, ctx: &mut Ctx) {
        ctx.set_timer(1, 0);
    }
    fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}
    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        if self.i >= self.topics.len() {
            return;
        }
        let t = self.topics[self.i].clone();
        self.i += 1;
        ctx.publish(&t, 256, Rc::new(()));
        ctx.set_timer(1, 0);
    }
}

/// End-to-end: 10k components subscribed on a 4-EC fabric, one
/// publisher per EC blasting through the trie-indexed `route`.
fn bench_fabric(n_comps: usize, pubs_per_ec: usize) {
    let num_ecs = 4;
    let groups = 64;
    let mut s = Stream::new(11);
    let mut rt = GraphRuntime::new(EdgeCloudNet::new(&NetConfig {
        num_ecs,
        ..Default::default()
    }));
    let hits = Rc::new(Cell::new(0u64));
    let filters = make_filters(n_comps, groups, &mut s);
    for (i, f) in filters.into_iter().enumerate() {
        let ec = i % num_ecs;
        rt.add(
            Site { cluster: ClusterRef::Ec(ec), node: format!("node{}", i % 7).into() },
            Box::new(Sink { filters: vec![f], hits: hits.clone() }),
        );
    }
    let mut total_pubs = 0usize;
    for ec in 0..num_ecs {
        let topics = make_names(pubs_per_ec, groups, &mut s);
        total_pubs += topics.len();
        rt.add(
            Site { cluster: ClusterRef::Ec(ec), node: "pub".into() },
            Box::new(Blaster { topics, i: 0 }),
        );
    }
    let t0 = Instant::now();
    rt.run(u64::MAX);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "fabric storm: {n_comps} comps, {total_pubs} publishes -> {} deliveries, \
         {} DES events in {:.2}s ({:.0} pubs/s)",
        hits.get(),
        rt.executed(),
        dt,
        total_pubs as f64 / dt
    );
    assert!(hits.get() > 0, "storm must reach subscribers");
}

fn main() {
    println!("# Routing index: trie vs linear scan (wildcard-heavy tables)\n");
    println!("| subscriptions | publishes | linear pubs/s | trie pubs/s | speedup |");
    println!("|---|---|---|---|---|");
    for n_subs in [100usize, 1_000, 10_000] {
        bench_index(n_subs, 20_000);
    }
    println!();
    bench_fabric(10_000, 2_000);
    println!("\nOK: trie agrees with the linear reference at every scale");
}
