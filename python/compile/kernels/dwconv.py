"""L1 Pallas kernel: depthwise 3x3 convolution (+ bias + ReLU epilogue).

Used by the MobileNetV2-style EOC's separable blocks. One grid step per
image: the padded (H+2, W+2, C) input plane is staged into VMEM and the
3x3 window is computed as nine shifted multiply-accumulates — the VMEM
analogue of the shared-memory halo scheme a CUDA depthwise kernel would
use (DESIGN.md §Hardware-Adaptation). Channels sit in the minor (lane)
dimension, so each MAC is a full-width vector op on the VPU.

Stride 2 is handled by computing the dense map and writing the strided
subsample — interpret-mode cost is identical and the HLO stays fusable.
Oracle: `ref.dwconv_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dw_kernel(x_ref, w_ref, b_ref, o_ref, *, hh, ww, stride, sy, sx, act):
    """x_ref: (1, H+2, W+2, C) padded; w_ref: (3, 3, C); o_ref strided out.

    sy/sx are the subsample start offsets that align the dense (stride-1,
    pad-1) map with TF-style SAME padding at the requested stride: SAME
    uses pad_top = ((OH-1)*s + 3 - H)//2, and dense index i covers input
    rows [i-1, i+1], so out row j maps to dense row j*s + (1 - pad_top).
    """
    x = x_ref[0]
    acc = jnp.zeros((hh, ww, x.shape[-1]), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            acc += x[dy : dy + hh, dx : dx + ww, :] * w_ref[dy, dx, :]
    acc = acc + b_ref[...]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[0] = acc[sy::stride, sx::stride, :]


def dwconv(x, w, bias=None, stride=1, act="none"):
    """Depthwise 3x3, SAME padding.

    x: (N, H, W, C) f32; w: (3, 3, C); bias: (C,) or None.
    Output: (N, ceil(H/stride), ceil(W/stride), C).
    """
    n, h, wd, c = x.shape
    assert w.shape == (3, 3, c), (w.shape, c)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    oh = -(-h // stride)
    ow = -(-wd // stride)

    def _start(size, out):
        pad_top = max((out - 1) * stride + 3 - size, 0) // 2
        return 1 - pad_top

    sy, sx = _start(h, oh), _start(wd, ow)
    b = bias if bias is not None else jnp.zeros((c,), jnp.float32)
    return pl.pallas_call(
        functools.partial(_dw_kernel, hh=h, ww=wd, stride=stride,
                          sy=sy, sx=sx, act=act),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h + 2, wd + 2, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, c), lambda i: (0, 0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, c), jnp.float32),
        interpret=True,
    )(xp, w, b)


def vmem_bytes(h, w, c):
    """Per-step VMEM estimate: padded plane + weights + bias + dense out."""
    return 4 * ((h + 2) * (w + 2) * c + 9 * c + c + h * w * c)
