"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth (pytest + hypothesis assert the
Pallas kernels match them) AND the fast path used for build-time
training (`train.py` runs the ref implementations; the exported
inference HLO runs the Pallas path — both are asserted equivalent by
`tests/test_model.py::test_pallas_ref_parity`).
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, y, bias=None, act="none"):
    out = jnp.dot(x, y, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def dwconv_ref(x, w, bias=None, stride=1, act="none"):
    """Depthwise 3x3 SAME via lax.conv_general_dilated, NHWC."""
    c = x.shape[-1]
    # (3, 3, C) -> (3, 3, 1, C) HWIO with feature_group_count = C
    rhs = w.reshape(3, 3, 1, c)
    out = jax.lax.conv_general_dilated(
        x,
        rhs,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    if bias is not None:
        out = out + bias
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def framediff_ref(f0, f1, f2):
    """min of consecutive abs-diffs, then 3x3 box mean (zero padded)."""
    m = jnp.minimum(jnp.abs(f1 - f0), jnp.abs(f2 - f1))
    h, w = m.shape
    mp = jnp.pad(m, ((1, 1), (1, 1)))
    acc = jnp.zeros_like(m)
    for dy in range(3):
        for dx in range(3):
            acc = acc + mp[dy : dy + h, dx : dx + w]
    return acc / 9.0
