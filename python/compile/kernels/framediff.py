"""L1 Pallas kernel: three-frame differencing motion score map.

This is the paper's OD (Object Detector): SurveilEdge-style frame
differencing replaces a heavy detector on resource-limited edge nodes
(§5.1.2). The rust OD has a native implementation on its hot path; this
kernel is the XLA-offload variant (`--od-xla`) and an L1 deliverable,
exercised by the `framediff.hlo.txt` artifact and the OD ablation bench.

score(y, x) = box3x3( min(|f1 - f0|, |f2 - f1|) )

i.e. motion must be present across BOTH consecutive frame pairs (this
suppresses single-frame noise), then a 3x3 box filter suppresses isolated
pixels. The rust connected-component pass thresholds this map into crop
boxes.

Schedule: one grid step stages all three (H, W) frames into VMEM — at the
synthetic 96x160 resolution that is 3 * 60 KiB in + 60 KiB out, far under
the ~16 MiB VMEM budget, so halo banding would only add grid overhead
(see EXPERIMENTS.md §Perf L1 for the footprint table; 1080p would need
the banded variant). Oracle: `ref.framediff_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fd_kernel(f0_ref, f1_ref, f2_ref, o_ref, *, h, w):
    d1 = jnp.abs(f1_ref[...] - f0_ref[...])
    d2 = jnp.abs(f2_ref[...] - f1_ref[...])
    m = jnp.minimum(d1, d2)
    mp = jnp.pad(m, ((1, 1), (1, 1)))
    acc = jnp.zeros((h, w), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            acc += mp[dy : dy + h, dx : dx + w]
    o_ref[...] = acc * jnp.float32(1.0 / 9.0)


def framediff(f0, f1, f2):
    """Motion score map for three consecutive (H, W) grayscale frames."""
    h, w = f0.shape
    assert f1.shape == (h, w) and f2.shape == (h, w)
    spec = pl.BlockSpec((h, w), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_fd_kernel, h=h, w=w),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(f0, f1, f2)


def vmem_bytes(h, w):
    """VMEM estimate: 3 frames + padded min-map + accumulator + out."""
    return 4 * (3 * h * w + (h + 2) * (w + 2) + 2 * h * w)
