"""L1 Pallas kernel: tiled matmul with fused bias + activation epilogue.

This is the compute workhorse of both classifiers: every convolution is
expressed as im2col (L2, `model.py`) followed by this kernel, and the
dense heads call it directly. The design targets the TPU MXU (DESIGN.md
§Hardware-Adaptation):

  * grid (M/bm, N/bn, K/bk) with a VMEM accumulator scratch — the
    classic HBM->VMEM block schedule (the role threadblock tiling plays
    in the paper's GPU baselines);
  * blocks default to 128x128 (MXU native tile); K is innermost so each
    (i, j) output tile stays resident in VMEM across the K sweep;
  * bias add + ReLU are fused into the epilogue of the last K step, so
    the activation never round-trips to HBM.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (see aot_recipe).
Correctness oracle: `ref.matmul_ref` (pytest + hypothesis sweeps).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, b_ref, o_ref, acc_ref, *, nk, act, has_bias):
    """One (bm, bn) output tile; K swept by the innermost grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if has_bias:
            out = out + b_ref[...]  # (1, bn) broadcast over rows
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def _ceil_to(v, m):
    return -(-v // m) * m


def pick_blocks(m, n, k, bm=128, bn=128, bk=128):
    """Shrink default 128^3 blocks for small operands (less pad waste).

    Keeps the lane dimension at >= 8 and the sublane at >= 8 so the
    blocks stay aligned with the (8, 128) TPU vreg tiling.
    """
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    return bm, bn, bk


def matmul(x, y, bias=None, act="none", bm=1024, bn=128, bk=512):
    """act(x @ y + bias) via the Pallas kernel.

    x: (M, K) f32; y: (K, N) f32; bias: (N,) f32 or None;
    act: "none" | "relu". Operands are zero-padded to block multiples and
    the result sliced back — zero padding is exact for matmul + bias
    broadcast (padded rows/cols are discarded before any nonlinearity is
    observed by the caller).

    Default tiles (1024, 128, 512) are the §Perf-tuned operating point:
    interpret-mode grids pay an O(output) copy per step, so fewer/larger
    tiles cut COC b=1 latency 4.4x vs 128^3 (EXPERIMENTS.md §Perf L1)
    while the VMEM footprint (~3.3 MiB, `vmem_bytes`) still fits the
    16 MiB budget a real TPU core would impose. `pick_blocks` shrinks
    them automatically for small operands.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = pick_blocks(m, n, k, bm, bn, bk)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    has_bias = bias is not None
    if has_bias:
        bp = jnp.pad(bias.reshape(1, -1), ((0, 0), (0, np_ - n)))
    else:
        bp = jnp.zeros((1, np_), jnp.float32)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(
            _matmul_kernel, nk=grid[2], act=act, has_bias=has_bias
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, yp, bp)
    return out[:m, :n]


def vmem_bytes(bm=128, bn=128, bk=128):
    """Static VMEM footprint estimate of one grid step (f32).

    x-tile + y-tile + bias + out-tile + accumulator. Used by the §Perf
    analysis in EXPERIMENTS.md (interpret mode has no real VMEM).
    """
    return 4 * (bm * bk + bk * bn + bn + 2 * bm * bn)
