"""L1 Pallas kernels (interpret=True) + pure-jnp oracles.

Kernels: `matmul` (tiled MXU matmul, fused bias/ReLU), `dwconv`
(depthwise 3x3), `framediff` (3-frame motion score). See each module's
docstring for the BlockSpec schedule and the VMEM footprint estimator
used by EXPERIMENTS.md §Perf.
"""

from .matmul import matmul, pick_blocks
from .dwconv import dwconv
from .framediff import framediff
from . import ref

__all__ = ["matmul", "pick_blocks", "dwconv", "framediff", "ref"]
