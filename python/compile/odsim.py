"""Python mirror of the rust frame pipeline (synth camera + OD).

The paper trains EOC on crops *extracted from historical video by the
same frame-differencing OD that runs online* (§5.1.2). To close the
train/serve domain gap we reproduce that: this module mirrors
`rust/src/video/synth.rs` (CameraStream) and `rust/src/video/od.rs`
(motion map + connected components + crop extraction) so `data.py` can
build training sets whose distribution IS the serving distribution.

Bit-exactness with rust is guaranteed for the underlying primitives
(same SplitMix64 streams, same integer geometry via scenes.py); the
frame/OD layer mirrors the rust logic operation-for-operation, and
`python/tests/test_odsim.py` checks the invariants.
"""

import numpy as np

from . import prng, scenes

FRAME_H, FRAME_W = 96, 160
NOISE_SIGMA = np.float32(0.06)
FPS = 30.0

# OdConfig defaults — keep in sync with rust/src/video/od.rs
OD_THRESHOLD = 0.06
OD_MIN_AREA = 16
OD_MAX_CROPS = 2

# class sampling percentages — mirrors rust CLASS_PCT / aot EOC_WEIGHTS
CLASS_PCT = [14, 25, 8, 8, 8, 21, 8, 8]


def sample_class(u):
    v = int(u) % 100
    for c, p in enumerate(CLASS_PCT):
        if v < p:
            return c
        v -= p
    return 7


def _sc(v, s8):
    return (v * s8) // 8


class MovingObject:
    __slots__ = ("cls", "seed", "x0", "y", "vx", "s8", "t0")

    def __init__(self, cls, seed, x0, y, vx, s8, t0):
        self.cls = cls
        self.seed = seed
        self.x0 = x0
        self.y = y
        self.vx = vx
        self.s8 = s8
        self.t0 = t0

    def x_at(self, t):
        return int(round(self.x0 + self.vx * (t - self.t0)))

    def center_at(self, t):
        return (self.y + _sc(16, self.s8), self.x_at(t) + _sc(16, self.s8))


class CameraStream:
    """Mirror of rust video::synth::CameraStream."""

    def __init__(self, cam_seed, slots):
        self.cam_seed = cam_seed
        self.h, self.w = FRAME_H, FRAME_W
        self.fps = FPS
        self.respawns = [0] * slots
        self.slots = [self._spawn(i, 0, 0.0) for i in range(slots)]

    def _spawn(self, slot, respawn, t):
        seed = int(prng.stream_u64(self.cam_seed, (slot << 32) | respawn, 1)[0])
        cls = sample_class(prng.u32_at(seed, 0))
        lanes = max(self.h // 36, 1)
        lane = prng.range_at(seed, 1, 0, lanes)
        vx = 25.0 + prng.f32_at(seed, 2) * 55.0
        s8 = prng.range_at(seed, 3, 6, 11)
        if respawn == 0:
            x0 = float(prng.range_at(seed, 4, -20, self.w - 20))
        else:
            x0 = -36.0
        return MovingObject(cls, seed, x0, lane * 36 + 2, vx, s8, t)

    def advance_to(self, t):
        for i, o in enumerate(self.slots):
            while self.slots[i].x_at(t) > self.w + 8:
                self.respawns[i] += 1
                self.slots[i] = self._spawn(i, self.respawns[i], t)

    def frame_at(self, t):
        img = np.zeros((self.h, self.w, 3), dtype=np.float32)
        fidx = int(round(t * self.fps))
        noise_seed = int(
            prng.stream_u64(self.cam_seed ^ 0xBACC0FF5, fidx, 1)[0]
        )
        paint_background_split(img, self.cam_seed, noise_seed, NOISE_SIGMA)
        for o in self.slots:
            scenes.render_object(img, o.cls, o.seed, o.x_at(t), o.y, o.s8)
        np.clip(img, 0.0, 1.0, out=img)
        return img


def paint_background_split(img, base_seed, noise_seed, sigma):
    """Mirror of rust paint_background_split (vectorized)."""
    h, w = img.shape[:2]
    g = np.float32(prng.f32_at(base_seed, 0) * 0.3 + 0.35)
    grad = np.float32(prng.f32_at(base_seed, 1) * 0.2 - 0.1)
    xx = np.arange(w, dtype=np.float32) / np.float32(w)
    base = (g + grad * xx)[None, :, None]
    n = prng.stream_f32(noise_seed, 16, h * w * 3).reshape(h, w, 3)
    img[...] = base + (n - np.float32(0.5)) * (np.float32(2.0) * sigma)


def gray(img):
    return img.mean(axis=2, dtype=np.float32)


def motion_map(f0, f1, f2):
    """min of consecutive abs diffs, 3x3 zero-padded box mean."""
    m = np.minimum(np.abs(f1 - f0), np.abs(f2 - f1))
    h, w = m.shape
    mp = np.pad(m, 1)
    acc = np.zeros_like(m)
    for dy in range(3):
        for dx in range(3):
            acc += mp[dy : dy + h, dx : dx + w]
    return acc / np.float32(9.0)


def find_regions(mmap, threshold=OD_THRESHOLD, min_area=OD_MIN_AREA,
                 max_crops=OD_MAX_CROPS):
    """4-connected components over mmap > threshold (BFS on sparse
    foreground). Returns [(cy, cx, area, score)] strongest-first."""
    h, w = mmap.shape
    fg = mmap > threshold
    seen = np.zeros_like(fg, dtype=bool)
    regions = []
    ys, xs = np.nonzero(fg)
    for y0, x0 in zip(ys, xs):
        if seen[y0, x0]:
            continue
        stack = [(int(y0), int(x0))]
        seen[y0, x0] = True
        area = 0
        sy = sx = 0
        score = 0.0
        while stack:
            y, x = stack.pop()
            area += 1
            sy += y
            sx += x
            score += float(mmap[y, x])
            for ny, nx in ((y - 1, x), (y + 1, x), (y, x - 1), (y, x + 1)):
                if 0 <= ny < h and 0 <= nx < w and fg[ny, nx] and not seen[ny, nx]:
                    seen[ny, nx] = True
                    stack.append((ny, nx))
        if area >= min_area:
            regions.append((sy // area, sx // area, area, score))
    regions.sort(key=lambda r: -r[3])
    return regions[:max_crops]


def extract_crop(frame, cy, cx):
    """32x32 RGB window centered at (cy, cx), clamped — mirror of rust."""
    c = scenes.CROP
    half = c // 2
    h, w = frame.shape[:2]
    y0 = int(np.clip(cy - half, 0, h - c))
    x0 = int(np.clip(cx - half, 0, w - c))
    return frame[y0 : y0 + c, x0 : x0 + c, :].copy(), (y0, x0)


def label_crop(cam, t, y0, x0, max_center_dist=14):
    """Geometric ground-truth label for a crop window: the class of the
    visible object whose center is nearest the window center (within
    max_center_dist), else background (0)."""
    c = scenes.CROP
    wy, wx = y0 + c // 2, x0 + c // 2
    best = None
    for o in cam.slots:
        oy, ox = o.center_at(t)
        d = max(abs(oy - wy), abs(ox - wx))
        if d <= max_center_dist and (best is None or d < best[0]):
            best = (d, o.cls)
    return best[1] if best is not None else 0


def make_od_dataset(n_crops, seed, cams=6, slots=2, t_start=1.0, dt=0.35):
    """Crops extracted by the OD pipeline from synthetic camera streams,
    with geometric labels — the §5.1.2 'historical video' training set.

    Returns (X[n,32,32,3] f32, y[n] int32).
    """
    streams = [CameraStream(seed * 7919 + i, slots) for i in range(cams)]
    X = np.empty((n_crops, scenes.CROP, scenes.CROP, 3), dtype=np.float32)
    y = np.empty(n_crops, dtype=np.int32)
    got = 0
    step = 0
    while got < n_crops:
        cam = streams[step % cams]
        t = t_start + (step // cams) * dt
        step += 1
        cam.advance_to(t)
        f0 = gray(cam.frame_at(t - 0.2))
        f1g = cam.frame_at(t - 0.1)
        f2 = gray(cam.frame_at(t))
        mmap = motion_map(f0, gray(f1g), f2)
        for cy, cx, _area, _score in find_regions(mmap):
            crop, (y0, x0) = extract_crop(f1g, cy, cx)
            X[got] = crop
            y[got] = label_crop(cam, t - 0.1, y0, x0)
            got += 1
            if got >= n_crops:
                break
    return X, y
