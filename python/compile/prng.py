"""Deterministic, stateless cross-language PRNG (SplitMix64-indexed).

The same generator is implemented in rust (`rust/src/util/prng.rs`). Both
sides must produce bit-identical streams so that the procedural scene
renderer (python: training data; rust: live video frames) draws identical
pixels — this is asserted by the golden cross-language test
(`rust/tests/golden_scenes.rs` vs `python/tests/test_scenes.py`).

Design: value i of stream `seed` is splitmix64(seed + (i+1)*GOLDEN).
Stateless indexing vectorizes trivially in numpy (no sequential state),
which keeps dataset generation fast while the rust side uses plain loops.
"""

import numpy as np

GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)

_err = np.geterr()


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Finalizer of SplitMix64. Input/output uint64 arrays (wrapping)."""
    old = np.seterr(over="ignore")
    try:
        z = x.astype(np.uint64)
        z ^= z >> np.uint64(30)
        z *= _M1
        z ^= z >> np.uint64(27)
        z *= _M2
        z ^= z >> np.uint64(31)
        return z
    finally:
        np.seterr(**old)


def stream_u64(seed: int, start: int, n: int) -> np.ndarray:
    """Values [start, start+n) of stream `seed` as uint64."""
    old = np.seterr(over="ignore")
    try:
        idx = np.arange(start + 1, start + n + 1, dtype=np.uint64)
        return splitmix64(np.uint64(seed) + idx * GOLDEN)
    finally:
        np.seterr(**old)


def stream_u32(seed: int, start: int, n: int) -> np.ndarray:
    """Top 32 bits — matches rust `u32_at`."""
    return (stream_u64(seed, start, n) >> np.uint64(32)).astype(np.uint32)


def stream_f32(seed: int, start: int, n: int) -> np.ndarray:
    """Uniform [0,1) f32 from the top 24 bits — matches rust `f32_at`."""
    u = stream_u32(seed, start, n)
    return ((u >> np.uint32(8)).astype(np.float32)) * np.float32(1.0 / (1 << 24))


def u32_at(seed: int, i: int) -> int:
    return int(stream_u32(seed, i, 1)[0])


def f32_at(seed: int, i: int) -> float:
    return float(stream_f32(seed, i, 1)[0])


def range_at(seed: int, i: int, lo: int, hi: int) -> int:
    """Integer in [lo, hi) — matches rust `range_at` (modulo reduction)."""
    assert hi > lo
    return lo + int(u32_at(seed, i) % (hi - lo))
