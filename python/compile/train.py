"""Build-time training for EOC / COC (hand-rolled SGD + momentum).

The offline environment has no optax; the optimizer is ~30 lines and
lives here. Training runs ONCE inside `make artifacts` (aot.py) on the
ref (pure-jnp) forward path — fast under jit — then the trained weights
are folded and exported through the Pallas inference path.

Mirrors the paper's §5.1.2 asymmetry: COC is trained longer and larger
(the stand-in for ImageNet-pretrained ResNet152); EOC is trained
"on the fly" — few epochs, tiny model — like the paper's
query-triggered MobileNetV2.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def ce_loss(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def l2_penalty(params):
    return sum(
        jnp.sum(l * l)
        for l in jax.tree_util.tree_leaves(params)
        if l.ndim > 1  # weights only, not biases/gains
    )


def sgd_momentum(params, grads, vel, lr, mom=0.9):
    new_vel = jax.tree_util.tree_map(
        lambda v, g: mom * v + g, vel, grads
    )
    new_params = jax.tree_util.tree_map(
        lambda p, v: p - lr * v, params, new_vel
    )
    return new_params, new_vel


def make_step(apply_fn, weight_decay):
    """Returns a jitted (params, state, vel, x, y, lr) -> ... step."""

    def loss_fn(params, state, x, y):
        logits, new_state = apply_fn(params, state, x, train=True)
        loss = ce_loss(logits, y) + weight_decay * l2_penalty(params)
        return loss, new_state

    @jax.jit
    def step(params, state, vel, x, y, lr):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, state, x, y)
        params, vel = sgd_momentum(params, grads, vel, lr)
        return params, new_state, vel, loss

    return step


def cosine_lr(base, epoch, total):
    return base * 0.5 * (1.0 + np.cos(np.pi * epoch / total))


def train_model(
    apply_fn,
    params,
    state,
    X,
    y,
    epochs,
    batch=128,
    base_lr=0.05,
    weight_decay=1e-4,
    seed=0,
    log=print,
    tag="model",
):
    """Generic training loop. Returns (params, state, history)."""
    step = make_step(apply_fn, weight_decay)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    n = len(X)
    history = []
    for ep in range(epochs):
        t0 = time.time()
        Xa, ya = data.augment(X, y, seed * 997 + ep)
        order = np.random.default_rng(seed * 131 + ep).permutation(n)
        lr = jnp.float32(cosine_lr(base_lr, ep, epochs))
        losses = []
        for b0 in range(0, n - batch + 1, batch):
            idx = order[b0 : b0 + batch]
            params, state, vel, loss = step(
                params, state, vel, Xa[idx], ya[idx], lr
            )
            losses.append(float(loss))
        ep_loss = float(np.mean(losses))
        history.append(ep_loss)
        log(
            f"[{tag}] epoch {ep + 1}/{epochs} loss={ep_loss:.4f} "
            f"lr={float(lr):.4f} ({time.time() - t0:.1f}s)"
        )
    return params, state, history


def evaluate(apply_fn, params, state, X, y, batch=256):
    """Top-1 accuracy on (X, y) in eval mode (ref path)."""
    correct = 0
    for b0 in range(0, len(X), batch):
        logits, _ = apply_fn(
            params, state, X[b0 : b0 + batch], train=False
        )
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[b0 : b0 + batch]))
    return correct / len(X)


def eval_binary(apply_fn, params, state, X, y, batch=256, thresh=0.5):
    """Binary error rate + confidence stats for EOC-style heads."""
    confs = []
    for b0 in range(0, len(X), batch):
        logits, _ = apply_fn(
            params, state, X[b0 : b0 + batch], train=False
        )
        confs.append(np.asarray(jax.nn.softmax(logits, -1))[:, 1])
    conf = np.concatenate(confs)
    pred = (conf >= thresh).astype(np.int32)
    err = float(np.mean(pred != y))
    return err, conf
