"""AOT entry point: train models once, lower inference to HLO text.

`make artifacts` runs `python -m compile.aot --out ../artifacts`. This is
the ONLY time python executes — the rust coordinator consumes the
emitted `*.hlo.txt` + `manifest.json` and is self-contained afterwards.

Interchange format is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the `xla`
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). Lowered with return_tuple=True, so rust
unwraps with `to_tuple1()`.

Emitted artifacts:
  eoc_b{B}.hlo.txt / coc_b{B}.hlo.txt  — folded-BN inference graphs with
      trained weights embedded as constants, B in BATCH_SIZES;
  framediff.hlo.txt                    — OD motion-score kernel (96x160);
  fl_train_step.hlo.txt                — one SGD step of a logistic
      model (the ECC-training example's per-client step);
  manifest.json                        — shapes, batch sizes, measured
      accuracies, renderer constants;
  golden/scenes.json + golden/crops.bin — cross-language golden crops +
      expected model outputs (asserted by rust integration tests).
"""

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, odsim, scenes, train
from .kernels.framediff import framediff as framediff_kernel

BATCH_SIZES = (1, 2, 4, 8, 16)
FRAME_H, FRAME_W = 96, 160
FL_DIM, FL_CLASSES, FL_BATCH = 16, 2, 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the trained weights are baked into the graph
    # as constants; the default printer elides them as `{...}` which the
    # rust-side text parser would (correctly) reject.
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(infer_fn, folded, batch, use_pallas=True) -> str:
    spec = jax.ShapeDtypeStruct((batch, scenes.CROP, scenes.CROP, 3),
                                jnp.float32)
    fn = lambda x: (infer_fn(folded, x, use_pallas=use_pallas),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_framediff() -> str:
    spec = jax.ShapeDtypeStruct((FRAME_H, FRAME_W), jnp.float32)
    fn = lambda f0, f1, f2: (framediff_kernel(f0, f1, f2),)
    return to_hlo_text(jax.jit(fn).lower(spec, spec, spec))


def fl_train_step(w, b, x, y, lr):
    """One SGD step of 2-class logistic regression — the per-client step
    of the `federated_training_sim` example (ECC-training pattern, §2)."""
    def loss_fn(w, b):
        logits = x @ w + b
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(w, b)
    return w - lr * grads[0], b - lr * grads[1], loss


def lower_fl() -> str:
    specs = (
        jax.ShapeDtypeStruct((FL_DIM, FL_CLASSES), jnp.float32),
        jax.ShapeDtypeStruct((FL_CLASSES,), jnp.float32),
        jax.ShapeDtypeStruct((FL_BATCH, FL_DIM), jnp.float32),
        jax.ShapeDtypeStruct((FL_BATCH,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return to_hlo_text(jax.jit(fl_train_step).lower(*specs))


# EOC training distribution: boost the target and its confuser so the
# binary head sees enough positives (paper: query-specific training set).
EOC_WEIGHTS = np.array([0.14, 0.25, 0.08, 0.08, 0.08, 0.21, 0.08, 0.08])

GOLDEN_SCENES = [(c, 7000 + 13 * i + c) for i, c in enumerate(
    [0, 1, 2, 3, 4, 5, 6, 7, 1, 5, 1, 2, 0, 7, 4, 3])]


def build(out_dir, quick=False, log=print):
    t0 = time.time()
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    # Both models train on crops extracted by the SAME frame-differencing
    # OD that runs online (odsim mirrors the rust pipeline) — this is
    # the paper's own recipe ("crops extracted from historical video",
    # §5.1.2) and closes the train/serve domain gap. COC is sized for
    # near-oracle accuracy (it is also the post-hoc ground-truth
    # labeller, footnote 1); EOC is an "on-the-fly" train whose ~5-12%
    # binary error mirrors the paper's 11.06% vs 4.49% asymmetry.
    n_coc_train = 600 if quick else 5000
    n_coc_test = 240 if quick else 1200
    n_eoc_train = 300 if quick else 3000
    n_eoc_test = 200 if quick else 1200
    coc_epochs = 1 if quick else 8
    eoc_epochs = 1 if quick else 5
    batch_sizes = (1, 4) if quick else BATCH_SIZES

    log(f"[aot] building OD-extracted crop datasets (quick={quick})")
    Xc, yc = odsim.make_od_dataset(n_coc_train, seed=11)
    Xct, yct = odsim.make_od_dataset(n_coc_test, seed=22)
    Xe, ye8 = odsim.make_od_dataset(n_eoc_train, seed=33)
    Xet, yet8 = odsim.make_od_dataset(n_eoc_test, seed=44)
    ye, yet = data.binary_labels(ye8), data.binary_labels(yet8)

    log("[aot] training COC (cloud classifier)")
    cp, cs = model.init_coc(seed=0)
    cp, cs, chist = train.train_model(
        model.coc_apply, cp, cs, Xc, yc, epochs=coc_epochs,
        batch=64, base_lr=0.05, tag="coc", log=log,
    )
    coc_top1 = train.evaluate(model.coc_apply, cp, cs, Xct, yct)
    log(f"[aot] COC top-1 accuracy: {coc_top1:.4f} "
        f"({model.count_params(cp)} params)")

    log("[aot] training EOC (edge binary classifier, on-the-fly style)")
    ep_, es = model.init_eoc(seed=1)
    ep_, es, ehist = train.train_model(
        model.eoc_apply, ep_, es, Xe, ye, epochs=eoc_epochs,
        batch=64, base_lr=0.08, tag="eoc", log=log,
    )
    eoc_err, _ = train.eval_binary(model.eoc_apply, ep_, es, Xet, yet)
    log(f"[aot] EOC binary error: {eoc_err:.4f} "
        f"({model.count_params(ep_)} params)")

    folded_coc = model.fold_coc(cp, cs)
    folded_eoc = model.fold_eoc(ep_, es)

    files = {}
    for b in batch_sizes:
        for name, infer, folded in (
            ("eoc", model.eoc_infer, folded_eoc),
            ("coc", model.coc_infer, folded_coc),
        ):
            path = f"{name}_b{b}.hlo.txt"
            log(f"[aot] lowering {path}")
            text = lower_model(infer, folded, b)
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            files.setdefault(name, []).append(path)

    log("[aot] lowering framediff.hlo.txt")
    with open(os.path.join(out_dir, "framediff.hlo.txt"), "w") as f:
        f.write(lower_framediff())
    log("[aot] lowering fl_train_step.hlo.txt")
    with open(os.path.join(out_dir, "fl_train_step.hlo.txt"), "w") as f:
        f.write(lower_fl())

    # ---- goldens: crops + expected model outputs (pallas path) ----
    log("[aot] writing golden crops + expected outputs")
    crops = np.stack([scenes.make_crop(c, s) for c, s in GOLDEN_SCENES])
    with open(os.path.join(out_dir, "golden", "crops.bin"), "wb") as f:
        f.write(struct.pack("<III", len(crops), scenes.CROP, 3))
        f.write(crops.astype("<f4").tobytes())
    eoc_probs = np.asarray(model.eoc_infer(folded_eoc, jnp.asarray(crops),
                                           use_pallas=True))
    coc_probs = np.asarray(model.coc_infer(folded_coc, jnp.asarray(crops),
                                           use_pallas=True))
    golden = {
        "scenes": [
            {"cls": int(c), "seed": int(s)} for c, s in GOLDEN_SCENES
        ],
        "eoc_probs": [[float(v) for v in row] for row in eoc_probs],
        "coc_probs": [[float(v) for v in row] for row in coc_probs],
    }
    with open(os.path.join(out_dir, "golden", "scenes.json"), "w") as f:
        json.dump(golden, f, indent=1)

    manifest = {
        "version": 1,
        "crop": scenes.CROP,
        "classes": scenes.CLASSES,
        "target_class": scenes.TARGET_CLASS,
        "frame": {"h": FRAME_H, "w": FRAME_W},
        "models": {
            "eoc": {
                "files": files["eoc"],
                "batch_sizes": list(batch_sizes),
                "outputs": 2,
                "params": model.count_params(ep_),
                "binary_error": eoc_err,
                "train_loss": ehist,
            },
            "coc": {
                "files": files["coc"],
                "batch_sizes": list(batch_sizes),
                "outputs": scenes.NUM_CLASSES,
                "params": model.count_params(cp),
                "top1": coc_top1,
                "train_loss": chist,
            },
        },
        "framediff": {"file": "framediff.hlo.txt",
                      "h": FRAME_H, "w": FRAME_W},
        "fl": {"file": "fl_train_step.hlo.txt", "dim": FL_DIM,
               "classes": FL_CLASSES, "batch": FL_BATCH},
        "golden": {"scenes": "golden/scenes.json",
                   "crops_bin": "golden/crops.bin"},
        "build_seconds": round(time.time() - t0, 1),
        "quick": quick,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"[aot] done in {manifest['build_seconds']}s -> {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training + fewer batch sizes (tests)")
    args = ap.parse_args()
    build(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
