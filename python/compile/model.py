"""L2 JAX models: EOC (edge) and COC (cloud) classifiers.

The paper's video-query application (§5.1.2) uses:
  * COC — ResNet152 on the Central Cloud: accurate multi-class
    classification. Here: a ResNet-style 8-class CNN over 32x32 crops.
  * EOC — MobileNetV2 trained on the fly, deployed on edge nodes:
    lightweight binary ("is the queried object present") classification.
    Here: a tiny depthwise-separable CNN with a 2-way head.

Both are pure-functional: params/state are pytrees of jnp arrays. Every
convolution is im2col (this module) + the L1 Pallas `matmul` kernel;
EOC's depthwise stages call the L1 `dwconv` kernel. `use_pallas=False`
switches to the `ref` oracles — that path is used for build-time
training (fast under jit) and is asserted numerically equal to the
Pallas path by `tests/test_model.py`.

BatchNorm runs in batch-stats mode during training and is folded into
conv weights for export (`fold_conv_bn`), so the AOT-lowered inference
graph is conv + bias + relu only.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .kernels import ref
from .scenes import CROP, NUM_CLASSES

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# im2col convolution: patches (L2) + Pallas matmul (L1)
# ---------------------------------------------------------------------------


def _same_pads(size, stride):
    """TF-style SAME padding for a 3x3 window."""
    out = -(-size // stride)
    total = max((out - 1) * stride + 3 - size, 0)
    return total // 2, total - total // 2, out


def extract_patches_3x3(x, stride):
    """(N,H,W,C) -> (N*OH*OW, 9*C) patch matrix, SAME padding.

    The patch order (dy-major, then dx, then channel) must match the
    weight reshape in `conv3x3`.
    """
    n, h, w, c = x.shape
    pt, pb, oh = _same_pads(h, stride)
    pl_, pr, ow = _same_pads(w, stride)
    xp = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    cols = []
    for dy in range(3):
        for dx in range(3):
            sl = xp[:, dy : dy + (oh - 1) * stride + 1 : stride,
                    dx : dx + (ow - 1) * stride + 1 : stride, :]
            cols.append(sl)
    pat = jnp.concatenate(cols, axis=-1)  # (N, OH, OW, 9*C)
    return pat.reshape(n * oh * ow, 9 * c), (n, oh, ow)


def conv3x3(x, w, bias=None, stride=1, act="none", use_pallas=True):
    """3x3 conv, SAME. w: (3,3,Cin,Cout). Returns (N,OH,OW,Cout).

    Pallas path (the exported inference graph): im2col + the L1 matmul
    kernel. Ref path (build-time training + oracle): XLA's native conv —
    ~6x faster on this host and numerically equivalent (asserted by
    tests/test_model.py::test_pallas_ref_parity).
    """
    if use_pallas:
        cout = w.shape[-1]
        pat, (n, oh, ow) = extract_patches_3x3(x, stride)
        wm = w.reshape(-1, cout)
        out = kernels.matmul(pat, wm, bias=bias, act=act)
        return out.reshape(n, oh, ow, cout)
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        out = out + bias
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def conv1x1(x, w, bias=None, act="none", use_pallas=True):
    """Pointwise conv. w: (Cin, Cout)."""
    n, h, wd, c = x.shape
    mm = kernels.matmul if use_pallas else ref.matmul_ref
    out = mm(x.reshape(-1, c), w, bias=bias, act=act)
    return out.reshape(n, h, wd, -1)


def dense(x, w, bias=None, act="none", use_pallas=True):
    mm = kernels.matmul if use_pallas else ref.matmul_ref
    return mm(x, w, bias=bias, act=act)


def dwconv3x3(x, w, bias=None, stride=1, act="none", use_pallas=True):
    fn = kernels.dwconv if use_pallas else ref.dwconv_ref
    return fn(x, w, bias=bias, stride=stride, act=act)


# ---------------------------------------------------------------------------
# Conv + BatchNorm unit (training) and its folded inference form
# ---------------------------------------------------------------------------


def init_conv_bn(rng, cin, cout, pointwise=False):
    fan_in = cin if pointwise else 9 * cin
    std = np.sqrt(2.0 / fan_in)
    shape = (cin, cout) if pointwise else (3, 3, cin, cout)
    return {
        "w": jnp.asarray(rng.standard_normal(shape) * std, jnp.float32),
        "gamma": jnp.ones((cout,), jnp.float32),
        "beta": jnp.zeros((cout,), jnp.float32),
    }


def init_conv_bn_state(cout):
    return {
        "mu": jnp.zeros((cout,), jnp.float32),
        "var": jnp.ones((cout,), jnp.float32),
    }


def conv_bn(p, s, x, stride=1, act="none", train=False, use_pallas=True,
            pointwise=False):
    """conv -> BN -> act. Returns (y, new_state)."""
    if pointwise:
        y = conv1x1(x, p["w"], use_pallas=use_pallas)
    else:
        y = conv3x3(x, p["w"], stride=stride, use_pallas=use_pallas)
    if train:
        mu = jnp.mean(y, axis=(0, 1, 2))
        var = jnp.var(y, axis=(0, 1, 2))
        new_s = {
            "mu": BN_MOMENTUM * s["mu"] + (1 - BN_MOMENTUM) * mu,
            "var": BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mu, var = s["mu"], s["var"]
        new_s = s
    y = (y - mu) * jax.lax.rsqrt(var + BN_EPS) * p["gamma"] + p["beta"]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    return y, new_s


def fold_conv_bn(p, s):
    """Fold BN stats into conv weights: returns {"w", "b"}."""
    scale = p["gamma"] / jnp.sqrt(s["var"] + BN_EPS)
    w = p["w"] * scale  # broadcast over trailing Cout axis
    b = p["beta"] - s["mu"] * scale
    return {"w": w, "b": b}


# ---------------------------------------------------------------------------
# COC: ResNet-style 8-class classifier
# ---------------------------------------------------------------------------

COC_CHANNELS = (16, 32, 64)
COC_BLOCKS = (1, 1, 1)


def init_coc(seed=0):
    rng = np.random.RandomState(seed)
    params = {"stem": init_conv_bn(rng, 3, COC_CHANNELS[0])}
    state = {"stem": init_conv_bn_state(COC_CHANNELS[0])}
    stages = []
    sstate = []
    cin = COC_CHANNELS[0]
    for si, (c, nb) in enumerate(zip(COC_CHANNELS, COC_BLOCKS)):
        stage = {}
        st = {}
        if si > 0:
            stage["down"] = init_conv_bn(rng, cin, c)
            st["down"] = init_conv_bn_state(c)
        for bi in range(nb):
            stage[f"b{bi}c1"] = init_conv_bn(rng, c, c)
            stage[f"b{bi}c2"] = init_conv_bn(rng, c, c)
            st[f"b{bi}c1"] = init_conv_bn_state(c)
            st[f"b{bi}c2"] = init_conv_bn_state(c)
        stages.append(stage)
        sstate.append(st)
        cin = c
    params["stages"] = stages
    state["stages"] = sstate
    params["head"] = {
        "w": jnp.asarray(
            rng.standard_normal((COC_CHANNELS[-1], NUM_CLASSES))
            * np.sqrt(1.0 / COC_CHANNELS[-1]),
            jnp.float32,
        ),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    return params, state


def coc_apply(params, state, x, train=False, use_pallas=False):
    """Logits of the COC. x: (N,32,32,3). Returns (logits, new_state)."""
    ns = {"stages": [dict() for _ in COC_CHANNELS]}
    y, ns["stem"] = conv_bn(
        params["stem"], state["stem"], x, act="relu", train=train,
        use_pallas=use_pallas,
    )
    for si, (c, nb) in enumerate(zip(COC_CHANNELS, COC_BLOCKS)):
        stage, st = params["stages"][si], state["stages"][si]
        if si > 0:
            y, ns["stages"][si]["down"] = conv_bn(
                stage["down"], st["down"], y, stride=2, act="relu",
                train=train, use_pallas=use_pallas,
            )
        for bi in range(nb):
            h1, s1 = conv_bn(
                stage[f"b{bi}c1"], st[f"b{bi}c1"], y, act="relu",
                train=train, use_pallas=use_pallas,
            )
            h2, s2 = conv_bn(
                stage[f"b{bi}c2"], st[f"b{bi}c2"], h1, act="none",
                train=train, use_pallas=use_pallas,
            )
            y = jnp.maximum(y + h2, 0.0)
            ns["stages"][si][f"b{bi}c1"] = s1
            ns["stages"][si][f"b{bi}c2"] = s2
    feat = jnp.mean(y, axis=(1, 2))
    logits = dense(
        feat, params["head"]["w"], params["head"]["b"], use_pallas=use_pallas
    )
    return logits, ns


def fold_coc(params, state):
    """Fold all BN units -> flat inference params."""
    f = {"stem": fold_conv_bn(params["stem"], state["stem"])}
    f["stages"] = []
    for si, (c, nb) in enumerate(zip(COC_CHANNELS, COC_BLOCKS)):
        stage, st = params["stages"][si], state["stages"][si]
        fs = {}
        if si > 0:
            fs["down"] = fold_conv_bn(stage["down"], st["down"])
        for bi in range(nb):
            fs[f"b{bi}c1"] = fold_conv_bn(stage[f"b{bi}c1"], st[f"b{bi}c1"])
            fs[f"b{bi}c2"] = fold_conv_bn(stage[f"b{bi}c2"], st[f"b{bi}c2"])
        f["stages"].append(fs)
    f["head"] = dict(params["head"])
    return f


def coc_infer(folded, x, use_pallas=True):
    """Folded-BN inference graph — the function lowered to HLO."""
    y = conv3x3(x, folded["stem"]["w"], folded["stem"]["b"], act="relu",
                use_pallas=use_pallas)
    for si, (c, nb) in enumerate(zip(COC_CHANNELS, COC_BLOCKS)):
        fs = folded["stages"][si]
        if si > 0:
            y = conv3x3(y, fs["down"]["w"], fs["down"]["b"], stride=2,
                        act="relu", use_pallas=use_pallas)
        for bi in range(nb):
            h = conv3x3(y, fs[f"b{bi}c1"]["w"], fs[f"b{bi}c1"]["b"],
                        act="relu", use_pallas=use_pallas)
            h = conv3x3(h, fs[f"b{bi}c2"]["w"], fs[f"b{bi}c2"]["b"],
                        act="none", use_pallas=use_pallas)
            y = jnp.maximum(y + h, 0.0)
    feat = jnp.mean(y, axis=(1, 2))
    logits = dense(feat, folded["head"]["w"], folded["head"]["b"],
                   use_pallas=use_pallas)
    return jax.nn.softmax(logits, axis=-1)


# ---------------------------------------------------------------------------
# EOC: MobileNetV2-style tiny binary classifier
# ---------------------------------------------------------------------------

# (cin, cout, stride) of the depthwise-separable blocks after the stem
EOC_BLOCKS = ((8, 16, 2), (16, 24, 2), (24, 32, 1))
EOC_STEM = 8


def init_eoc(seed=1):
    rng = np.random.RandomState(seed)
    params = {"stem": init_conv_bn(rng, 3, EOC_STEM)}
    state = {"stem": init_conv_bn_state(EOC_STEM)}
    blocks = []
    bstate = []
    for cin, cout, stride in EOC_BLOCKS:
        blk = {
            "dw_w": jnp.asarray(
                rng.standard_normal((3, 3, cin)) * np.sqrt(2.0 / 9.0),
                jnp.float32,
            ),
            "dw_b": jnp.zeros((cin,), jnp.float32),
            "pw": init_conv_bn(rng, cin, cout, pointwise=True),
        }
        blocks.append(blk)
        bstate.append({"pw": init_conv_bn_state(cout)})
    params["blocks"] = blocks
    state["blocks"] = bstate
    cfin = EOC_BLOCKS[-1][1]
    params["head"] = {
        "w": jnp.asarray(
            rng.standard_normal((cfin, 2)) * np.sqrt(1.0 / cfin), jnp.float32
        ),
        "b": jnp.zeros((2,), jnp.float32),
    }
    return params, state


def eoc_apply(params, state, x, train=False, use_pallas=False):
    """Logits (N, 2) of the EOC. Returns (logits, new_state)."""
    ns = {"blocks": [dict() for _ in EOC_BLOCKS]}
    y, ns["stem"] = conv_bn(
        params["stem"], state["stem"], x, stride=2, act="relu", train=train,
        use_pallas=use_pallas,
    )
    for bi, (cin, cout, stride) in enumerate(EOC_BLOCKS):
        blk, st = params["blocks"][bi], state["blocks"][bi]
        y = dwconv3x3(y, blk["dw_w"], blk["dw_b"], stride=stride, act="relu",
                      use_pallas=use_pallas)
        y, ns["blocks"][bi]["pw"] = conv_bn(
            blk["pw"], st["pw"], y, act="relu", train=train,
            use_pallas=use_pallas, pointwise=True,
        )
    feat = jnp.mean(y, axis=(1, 2))
    logits = dense(
        feat, params["head"]["w"], params["head"]["b"], use_pallas=use_pallas
    )
    return logits, ns


def fold_eoc(params, state):
    f = {"stem": fold_conv_bn(params["stem"], state["stem"])}
    f["blocks"] = []
    for bi, _ in enumerate(EOC_BLOCKS):
        blk, st = params["blocks"][bi], state["blocks"][bi]
        f["blocks"].append({
            "dw_w": blk["dw_w"],
            "dw_b": blk["dw_b"],
            "pw": fold_conv_bn(blk["pw"], st["pw"]),
        })
    f["head"] = dict(params["head"])
    return f


def eoc_infer(folded, x, use_pallas=True):
    """Folded-BN EOC inference — lowered to HLO. Returns (N,2) probs."""
    y = conv3x3(x, folded["stem"]["w"], folded["stem"]["b"], stride=2,
                act="relu", use_pallas=use_pallas)
    for bi, (cin, cout, stride) in enumerate(EOC_BLOCKS):
        fb = folded["blocks"][bi]
        y = dwconv3x3(y, fb["dw_w"], fb["dw_b"], stride=stride, act="relu",
                      use_pallas=use_pallas)
        y = conv1x1(y, fb["pw"]["w"], fb["pw"]["b"], act="relu",
                    use_pallas=use_pallas)
    feat = jnp.mean(y, axis=(1, 2))
    logits = dense(feat, folded["head"]["w"], folded["head"]["b"],
                   use_pallas=use_pallas)
    return jax.nn.softmax(logits, axis=-1)


def count_params(tree):
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))
