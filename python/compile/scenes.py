"""Procedural object renderer — the shared python<->rust scene spec.

The paper trains/evaluates on YouTube Live surveillance clips which we do
not have; DESIGN.md §Substitutions replaces them with procedural scenes
rendered identically by this module (training data, build time) and by
`rust/src/video/synth.rs` (live frames, run time). Determinism contract:

  * all geometry is integer arithmetic on pixel coordinates;
  * all colors / noise are f32 with draws taken from the indexed
    SplitMix64 streams in `prng.py` (mirrored bit-exactly in rust);
  * primitives are applied in a fixed documented order.

`rust/tests/golden_scenes.rs` renders the crops whose (class, seed) pairs
are listed in `artifacts/golden/scenes.json` and asserts bit-identical
pixels against the arrays written by `aot.py`.

Classes (index = label): 0 background, 1 motorcycle (the paper's query
target), 2 car, 3 person, 4 bus, 5 bicycle (motorcycle confuser),
6 truck, 7 dog.
"""

import numpy as np

from . import prng

CLASSES = [
    "background",
    "motorcycle",
    "car",
    "person",
    "bus",
    "bicycle",
    "truck",
    "dog",
]
NUM_CLASSES = len(CLASSES)
TARGET_CLASS = 1  # "motorcycle" — the query task of §5
CROP = 32  # crop side in pixels, input size of both classifiers

# ---------------------------------------------------------------------------
# Primitives. All take integer geometry in *image* coordinates and paint a
# solid f32 RGB color. Masks are computed with integer comparisons only.
# ---------------------------------------------------------------------------


def _grids(img):
    h, w = img.shape[:2]
    yy, xx = np.mgrid[0:h, 0:w]
    return yy, xx


def fill_rect(img, x0, y0, x1, y1, color):
    """Paint pixels with x0 <= x < x1 and y0 <= y < y1."""
    yy, xx = _grids(img)
    m = (xx >= x0) & (xx < x1) & (yy >= y0) & (yy < y1)
    img[m] = np.asarray(color, dtype=np.float32)


def fill_disk(img, cx, cy, r, color):
    """Paint pixels with (x-cx)^2 + (y-cy)^2 <= r^2."""
    yy, xx = _grids(img)
    m = (xx - cx) ** 2 + (yy - cy) ** 2 <= r * r
    img[m] = np.asarray(color, dtype=np.float32)


def fill_ring(img, cx, cy, r, w, color):
    """Paint pixels with (r-w)^2 <= d^2 <= r^2 (annulus of width w)."""
    yy, xx = _grids(img)
    d2 = (xx - cx) ** 2 + (yy - cy) ** 2
    inner = max(r - w, 0)
    m = (d2 <= r * r) & (d2 >= inner * inner)
    img[m] = np.asarray(color, dtype=np.float32)


# ---------------------------------------------------------------------------
# Object geometry. Base shapes live in a 32x32 box; `render_object` places
# the box at integer offset (ox, oy) with scale s8/8 (s8 in [6, 11)).
# ---------------------------------------------------------------------------

DARK = (0.08, 0.08, 0.10)  # wheels / outlines
LIGHT = (0.85, 0.88, 0.92)  # windows / highlights


def _sc(v, s8):
    """Scale a base-box coordinate (integer, floor division by 8)."""
    return (v * s8) // 8


def render_object(img, cls, seed, ox, oy, s8):
    """Draw one object of class `cls` into `img` (H,W,3 f32, in place).

    Geometry jitter and colors come from stream `seed` at fixed indices
    (0..15 reserved for the object). Index map: 3,4,5 = body RGB.
    Primitive order is part of the cross-language spec — do not reorder.
    """
    if cls == 0:
        return  # background: no object
    f = lambda i: prng.f32_at(seed, i)
    body = (
        np.float32(f(3) * 0.8 + 0.1),
        np.float32(f(4) * 0.8 + 0.1),
        np.float32(f(5) * 0.8 + 0.1),
    )

    def X(v):
        return ox + _sc(v, s8)

    def Y(v):
        return oy + _sc(v, s8)

    def R(v):
        return max(_sc(v, s8), 1)

    if cls == 1:  # motorcycle: two small filled wheels, low body, handlebar
        fill_rect(img, X(6), Y(14), X(26), Y(19), body)
        fill_rect(img, X(10), Y(10), X(18), Y(14), body)
        fill_rect(img, X(22), Y(8), X(24), Y(16), DARK)
        fill_disk(img, X(8), Y(24), R(4), DARK)
        fill_disk(img, X(24), Y(24), R(4), DARK)
    elif cls == 2:  # car: wide body + cabin + two wheels
        fill_rect(img, X(3), Y(12), X(29), Y(22), body)
        fill_rect(img, X(9), Y(6), X(23), Y(12), body)
        fill_rect(img, X(11), Y(7), X(21), Y(11), LIGHT)
        fill_disk(img, X(9), Y(23), R(3), DARK)
        fill_disk(img, X(23), Y(23), R(3), DARK)
    elif cls == 3:  # person: head + torso + two legs
        fill_disk(img, X(16), Y(7), R(3), body)
        fill_rect(img, X(13), Y(10), X(19), Y(22), body)
        fill_rect(img, X(13), Y(22), X(15), Y(29), DARK)
        fill_rect(img, X(17), Y(22), X(19), Y(29), DARK)
    elif cls == 4:  # bus: large box, window strip, two wheels
        fill_rect(img, X(3), Y(6), X(29), Y(24), body)
        fill_rect(img, X(5), Y(9), X(27), Y(13), LIGHT)
        fill_disk(img, X(9), Y(25), R(3), DARK)
        fill_disk(img, X(23), Y(25), R(3), DARK)
    elif cls == 5:  # bicycle: two RINGS (vs motorcycle's disks) + thin frame
        fill_ring(img, X(9), Y(22), R(5), max(_sc(2, s8), 1), DARK)
        fill_ring(img, X(23), Y(22), R(5), max(_sc(2, s8), 1), DARK)
        fill_rect(img, X(9), Y(13), X(23), Y(15), body)
        fill_rect(img, X(15), Y(9), X(17), Y(14), body)
    elif cls == 6:  # truck: trailer + cab + three wheels
        fill_rect(img, X(3), Y(8), X(20), Y(22), body)
        fill_rect(img, X(21), Y(12), X(29), Y(22), body)
        fill_rect(img, X(23), Y(13), X(28), Y(17), LIGHT)
        fill_disk(img, X(8), Y(23), R(3), DARK)
        fill_disk(img, X(16), Y(23), R(3), DARK)
        fill_disk(img, X(25), Y(23), R(3), DARK)
    elif cls == 7:  # dog: body + head + four legs + tail
        fill_rect(img, X(8), Y(14), X(24), Y(20), body)
        fill_disk(img, X(25), Y(12), R(3), body)
        fill_rect(img, X(9), Y(20), X(11), Y(26), body)
        fill_rect(img, X(13), Y(20), X(15), Y(26), body)
        fill_rect(img, X(17), Y(20), X(19), Y(26), body)
        fill_rect(img, X(21), Y(20), X(23), Y(26), body)
        fill_rect(img, X(6), Y(12), X(8), Y(16), body)
    else:
        raise ValueError(f"unknown class {cls}")


def paint_background(img, seed, sigma=np.float32(0.06)):
    """Textured background: base gray + horizontal gradient + pixel noise.

    Noise index for pixel (y, x, c) is (y*W + x)*3 + c of stream `seed` —
    the same row-major walk the rust loop performs.
    """
    h, w = img.shape[:2]
    g = np.float32(prng.f32_at(seed, 0) * 0.3 + 0.35)
    grad = np.float32(prng.f32_at(seed, 1) * 0.2 - 0.1)
    yy, xx = _grids(img)
    base = g + grad * (xx.astype(np.float32) / np.float32(w))
    img[...] = base[..., None].astype(np.float32)
    n = prng.stream_f32(seed, 16, h * w * 3).reshape(h, w, 3)
    img += (n - np.float32(0.5)) * (np.float32(2.0) * sigma)


def make_crop(cls, seed):
    """Render one 32x32 training/eval crop. Shared-spec entry point.

    Stream layout: geometry+colors from stream 2*seed+1, background and
    noise from stream 2*seed. Returns (32,32,3) f32 clipped to [0,1].
    """
    j = 2 * seed + 1
    b = 2 * seed
    img = np.zeros((CROP, CROP, 3), dtype=np.float32)
    paint_background(img, b)
    ox = prng.range_at(j, 0, -3, 4)
    oy = prng.range_at(j, 1, -3, 4)
    s8 = prng.range_at(j, 2, 6, 11)
    render_object(img, cls, j, ox, oy, s8)
    np.clip(img, 0.0, 1.0, out=img)
    return img
