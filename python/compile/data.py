"""Synthetic crop datasets for build-time training of EOC / COC.

The paper trains EOC on 14k crops extracted from historical video and
labelled by COC; COC (ResNet152) is ImageNet-pretrained. Without those
assets we train both networks on procedural crops (scenes.py). The class
list includes `bicycle` as a deliberate motorcycle confuser so the tiny
EOC is measurably weaker than COC — preserving the paper's accuracy
asymmetry (EOC 11.06% binary error vs COC 4.49% top-5 error) in shape.
"""

import numpy as np

from . import scenes
from .scenes import NUM_CLASSES, TARGET_CLASS


def make_crop_dataset(n, seed, class_weights=None):
    """n crops; labels drawn from class_weights (uniform by default).

    Returns (X[n,32,32,3] f32, y[n] int32). Crop i uses scene seed
    `seed*1_000_003 + i` so datasets with different seeds are disjoint.
    """
    if class_weights is None:
        class_weights = np.ones(NUM_CLASSES) / NUM_CLASSES
    class_weights = np.asarray(class_weights, dtype=np.float64)
    class_weights = class_weights / class_weights.sum()
    # label stream is independent of pixel streams
    from . import prng

    u = prng.stream_f32(seed ^ 0xABCDEF, 0, n).astype(np.float64)
    cdf = np.cumsum(class_weights)
    y = np.searchsorted(cdf, u, side="right").clip(0, NUM_CLASSES - 1)
    X = np.empty((n, scenes.CROP, scenes.CROP, 3), dtype=np.float32)
    for i in range(n):
        X[i] = scenes.make_crop(int(y[i]), seed * 1_000_003 + i)
    return X, y.astype(np.int32)


def binary_labels(y):
    """Multi-class -> binary 'is target (motorcycle)' labels."""
    return (y == TARGET_CLASS).astype(np.int32)


def augment(X, y, seed):
    """Cheap train-time augmentation: horizontal flip + integer roll.

    Pure numpy, deterministic. Doubles nothing — applied per epoch with a
    different seed to the same underlying set.
    """
    rng = np.random.default_rng(seed)
    X = X.copy()
    flip = rng.random(len(X)) < 0.5
    X[flip] = X[flip][:, :, ::-1, :]
    shifts = rng.integers(-2, 3, size=(len(X), 2))
    for i, (dy, dx) in enumerate(shifts):
        if dy or dx:
            X[i] = np.roll(X[i], (int(dy), int(dx)), axis=(0, 1))
    return X, y
