"""OD-pipeline mirror tests: the python frame/OD pipeline that builds
the training sets must behave like the rust serving pipeline."""

import numpy as np
import pytest

from compile import odsim, scenes
from compile.kernels import ref


def test_camera_stream_deterministic():
    a = odsim.CameraStream(100, 2)
    b = odsim.CameraStream(100, 2)
    for t in np.arange(0.0, 5.0, 0.5):
        a.advance_to(t)
        b.advance_to(t)
    np.testing.assert_array_equal(a.frame_at(5.0), b.frame_at(5.0))


def test_motion_map_matches_framediff_ref():
    cam = odsim.CameraStream(7, 2)
    cam.advance_to(1.2)
    f0 = odsim.gray(cam.frame_at(1.0))
    f1 = odsim.gray(cam.frame_at(1.1))
    f2 = odsim.gray(cam.frame_at(1.2))
    got = odsim.motion_map(f0, f1, f2)
    want = np.asarray(ref.framediff_ref(f0, f1, f2))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_moving_objects_are_detected():
    cam = odsim.CameraStream(9, 2)
    hits = 0
    for i in range(10):
        t = 1.0 + i * 0.5
        cam.advance_to(t)
        f0 = odsim.gray(cam.frame_at(t - 0.2))
        f1 = odsim.gray(cam.frame_at(t - 0.1))
        f2 = odsim.gray(cam.frame_at(t))
        hits += len(odsim.find_regions(odsim.motion_map(f0, f1, f2)))
    assert hits >= 5


def test_static_scene_no_regions():
    cam = odsim.CameraStream(55, 0)  # no objects: only sensor noise
    f0 = odsim.gray(cam.frame_at(0.0))
    f1 = odsim.gray(cam.frame_at(1 / 30))
    f2 = odsim.gray(cam.frame_at(2 / 30))
    assert odsim.find_regions(odsim.motion_map(f0, f1, f2)) == []


def test_extract_crop_clamps():
    cam = odsim.CameraStream(3, 1)
    f = cam.frame_at(0.0)
    crop, (y0, x0) = odsim.extract_crop(f, 0, 0)
    assert crop.shape == (32, 32, 3)
    assert (y0, x0) == (0, 0)
    crop, (y0, x0) = odsim.extract_crop(f, 95, 159)
    assert crop.shape == (32, 32, 3)


def test_make_od_dataset_labels_sane():
    X, y = odsim.make_od_dataset(150, seed=5)
    assert X.shape == (150, 32, 32, 3)
    assert X.dtype == np.float32
    assert ((y >= 0) & (y < scenes.NUM_CLASSES)).all()
    # motion crops should mostly contain objects, with the target class
    # well represented (it has the largest spawn weight)
    assert (y == scenes.TARGET_CLASS).mean() > 0.1
    assert (y != 0).mean() > 0.5


def test_od_dataset_deterministic():
    X1, y1 = odsim.make_od_dataset(40, seed=9)
    X2, y2 = odsim.make_od_dataset(40, seed=9)
    np.testing.assert_array_equal(X1, X2)
    np.testing.assert_array_equal(y1, y2)
