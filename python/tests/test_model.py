"""L2 model tests: shapes, Pallas/ref parity, BN folding, gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model

BATCH = 4


@pytest.fixture(scope="module")
def crops():
    X, y = data.make_crop_dataset(BATCH, seed=5)
    return X, y


def test_coc_shapes(crops):
    X, _ = crops
    p, s = model.init_coc()
    logits, ns = model.coc_apply(p, s, X, train=False)
    assert logits.shape == (BATCH, 8)
    # state structure preserved
    assert set(ns.keys()) == {"stem", "stages"}


def test_eoc_shapes(crops):
    X, _ = crops
    p, s = model.init_eoc()
    logits, _ = model.eoc_apply(p, s, X, train=False)
    assert logits.shape == (BATCH, 2)


def test_pallas_ref_parity(crops):
    """The exported (Pallas) inference graph must equal the training
    (ref/native-conv) graph numerically — the L1<->L2 contract."""
    X, _ = crops
    cp, cs = model.init_coc()
    fol = model.fold_coc(cp, cs)
    a = np.asarray(model.coc_infer(fol, X, use_pallas=False))
    b = np.asarray(model.coc_infer(fol, X, use_pallas=True))
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)

    ep, es = model.init_eoc()
    fe = model.fold_eoc(ep, es)
    a = np.asarray(model.eoc_infer(fe, X, use_pallas=False))
    b = np.asarray(model.eoc_infer(fe, X, use_pallas=True))
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_bn_folding_matches_eval_mode(crops):
    """Folded conv+bias inference == unfolded eval-mode BN forward."""
    X, _ = crops
    p, s = model.init_coc(seed=3)
    # make BN stats non-trivial
    s = jax.tree_util.tree_map(
        lambda a: a + 0.1 * jnp.arange(a.size, dtype=a.dtype).reshape(a.shape) / a.size,
        s,
    )
    logits, _ = model.coc_apply(p, s, X, train=False)
    probs_unfolded = jax.nn.softmax(logits, axis=-1)
    fol = model.fold_coc(p, s)
    probs_folded = model.coc_infer(fol, X, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(probs_unfolded), np.asarray(probs_folded), atol=1e-4, rtol=1e-4
    )


def test_probabilities_normalized(crops):
    X, _ = crops
    p, s = model.init_eoc()
    fe = model.fold_eoc(p, s)
    probs = np.asarray(model.eoc_infer(fe, X))
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, atol=1e-5)
    assert (probs >= 0).all()


def test_gradients_flow_everywhere(crops):
    """Every parameter leaf gets a nonzero gradient signal."""
    X, y = crops
    p, s = model.init_coc()

    def loss_fn(p):
        logits, _ = model.coc_apply(p, s, X, train=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), 1))

    grads = jax.grad(loss_fn)(p)
    leaves = jax.tree_util.tree_leaves(grads)
    assert len(leaves) > 5
    for g in leaves:
        assert bool(jnp.isfinite(g).all())
    nonzero = sum(int(jnp.any(g != 0)) for g in leaves)
    assert nonzero >= len(leaves) - 1, f"{nonzero}/{len(leaves)} leaves with signal"


def test_stride_conv_downsamples(crops):
    X, _ = crops
    w = np.random.default_rng(0).standard_normal((3, 3, 3, 5)).astype(np.float32)
    out = model.conv3x3(X, jnp.asarray(w), stride=2, use_pallas=False)
    assert out.shape == (BATCH, 16, 16, 5)


def test_param_counts():
    cp, _ = model.init_coc()
    ep, _ = model.init_eoc()
    # the paper's asymmetry: COC is orders of magnitude bigger than EOC
    assert model.count_params(cp) > 30 * model.count_params(ep)
