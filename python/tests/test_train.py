"""Build-time training sanity: loss decreases, eval helpers work."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data, model, train


def small_set(n=96, seed=1):
    return data.make_crop_dataset(n, seed=seed)


def test_training_reduces_loss():
    X, y = small_set(128)
    p, s = model.init_coc(seed=0)
    p, s, hist = train.train_model(
        model.coc_apply, p, s, X, y, epochs=3, batch=32, base_lr=0.05,
        log=lambda m: None,
    )
    assert hist[-1] < hist[0] * 0.98, f"no learning: {hist}"


def test_eval_binary_returns_confidences():
    X, y8 = small_set(64, seed=2)
    yb = data.binary_labels(y8)
    p, s = model.init_eoc(seed=1)
    err, conf = train.eval_binary(model.eoc_apply, p, s, X, yb)
    assert 0.0 <= err <= 1.0
    assert conf.shape == (64,)
    assert (conf >= 0).all() and (conf <= 1).all()


def test_sgd_momentum_moves_params():
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.ones((3,))}
    v = {"w": jnp.zeros((3,))}
    p2, v2 = train.sgd_momentum(p, g, v, lr=0.1)
    np.testing.assert_allclose(np.asarray(p2["w"]), 0.9)
    np.testing.assert_allclose(np.asarray(v2["w"]), 1.0)
    # momentum accumulates
    p3, v3 = train.sgd_momentum(p2, g, v2, lr=0.1)
    np.testing.assert_allclose(np.asarray(v3["w"]), 1.9)
    assert float(p3["w"][0]) < float(p2["w"][0])


def test_ce_loss_perfect_prediction_is_small():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    y = jnp.asarray([0, 1], dtype=jnp.int32)
    assert float(train.ce_loss(logits, y)) < 1e-4


def test_cosine_lr_decays_to_zero():
    assert train.cosine_lr(0.1, 0, 10) == 0.1
    assert train.cosine_lr(0.1, 10, 10) < 1e-9
    assert train.cosine_lr(0.1, 5, 10) < 0.1


def test_l2_penalty_skips_biases():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,)) * 100}
    # only the 2x2 weight contributes: 4.0
    assert float(train.l2_penalty(params)) == 4.0


def test_augment_preserves_labels_and_shape():
    X, y = small_set(16, seed=3)
    Xa, ya = data.augment(X, y, seed=0)
    assert Xa.shape == X.shape
    np.testing.assert_array_equal(y, ya)
    assert not np.array_equal(Xa, X)  # something flipped/shifted
