"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/strides; assert_allclose against ref.py is THE
core correctness signal for the compute layer (the same kernels are
lowered into the deployed HLO artifacts).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.matmul import pick_blocks, vmem_bytes

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu"]),
    with_bias=st.booleans(),
)
def test_matmul_matches_ref(m, k, n, act, with_bias):
    x = rand((m, k), m * 1000 + k)
    y = rand((k, n), n * 1000 + k)
    b = rand((n,), n) if with_bias else None
    got = kernels.matmul(x, y, bias=None if b is None else jnp.asarray(b), act=act)
    want = ref.matmul_ref(x, y, bias=b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_matmul_large_blocks_cross_tile_boundaries():
    # exercise multiple grid steps in every dimension
    x = rand((300, 260), 1)
    y = rand((260, 140), 2)
    got = kernels.matmul(x, y, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(got), x @ y, atol=5e-4, rtol=5e-4)


def test_matmul_relu_clamps_negatives():
    x = -np.ones((4, 4), np.float32)
    y = np.ones((4, 4), np.float32)
    got = np.asarray(kernels.matmul(x, y, act="relu"))
    assert (got == 0).all()


def test_pick_blocks_shrinks_for_small_operands():
    bm, bn, bk = pick_blocks(4, 9, 130)
    assert bm == 8 and bn == 16 and bk == 128
    assert pick_blocks(1000, 1000, 1000) == (128, 128, 128)


def test_vmem_estimate_is_positive_and_scales():
    assert vmem_bytes(128, 128, 128) > vmem_bytes(32, 32, 32)


# ---------------------------------------------------------------------------
# dwconv
# ---------------------------------------------------------------------------


@given(
    h=st.integers(4, 24),
    w=st.integers(4, 24),
    c=st.integers(1, 12),
    n=st.integers(1, 3),
    stride=st.sampled_from([1, 2]),
    act=st.sampled_from(["none", "relu"]),
)
def test_dwconv_matches_ref(h, w, c, n, stride, act):
    x = rand((n, h, w, c), h * 100 + w)
    k = rand((3, 3, c), c)
    b = rand((c,), c + 1)
    got = kernels.dwconv(x, k, bias=jnp.asarray(b), stride=stride, act=act)
    want = ref.dwconv_ref(x, k, bias=b, stride=stride, act=act)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_dwconv_identity_kernel_preserves_input():
    x = rand((1, 8, 8, 4), 3)
    k = np.zeros((3, 3, 4), np.float32)
    k[1, 1, :] = 1.0
    got = np.asarray(kernels.dwconv(x, k))
    np.testing.assert_allclose(got, x, atol=1e-6)


# ---------------------------------------------------------------------------
# framediff
# ---------------------------------------------------------------------------


@given(h=st.integers(4, 40), w=st.integers(4, 40))
def test_framediff_matches_ref(h, w):
    f = [rand((h, w), i) for i in range(3)]
    got = kernels.framediff(*f)
    want = ref.framediff_ref(*f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_framediff_static_scene_is_zero():
    f = rand((16, 16), 0)
    got = np.asarray(kernels.framediff(f, f, f))
    assert (got == 0).all()


def test_framediff_single_frame_flash_is_suppressed():
    # motion must appear in BOTH consecutive diffs; a one-frame flash
    # (f1 differs, f0 == f2) passes both diffs, but a flash only in f2
    # is suppressed by the min
    f0 = np.zeros((8, 8), np.float32)
    f2 = f0.copy()
    f2[4, 4] = 1.0
    got = np.asarray(kernels.framediff(f0, f0, f2))
    assert got.max() == 0.0
