"""Scene renderer determinism + coverage (python side of the shared
spec; the rust mirror is asserted bit-identical by golden tests)."""

import numpy as np
import pytest

from compile import prng, scenes


def test_prng_streams_are_stable_and_stateless():
    a = prng.stream_u32(42, 0, 8)
    b = np.array([prng.u32_at(42, i) for i in range(8)], dtype=np.uint32)
    np.testing.assert_array_equal(a, b)


def test_prng_f32_in_unit_interval():
    f = prng.stream_f32(7, 0, 10_000)
    assert (f >= 0).all() and (f < 1).all()
    # roughly uniform
    assert 0.45 < f.mean() < 0.55


def test_range_at_bounds():
    for i in range(500):
        v = prng.range_at(9, i, -3, 4)
        assert -3 <= v < 4


def test_make_crop_deterministic():
    a = scenes.make_crop(1, 123)
    b = scenes.make_crop(1, 123)
    np.testing.assert_array_equal(a, b)
    c = scenes.make_crop(1, 124)
    assert not np.array_equal(a, c)


@pytest.mark.parametrize("cls", range(scenes.NUM_CLASSES))
def test_all_classes_render_in_range(cls):
    img = scenes.make_crop(cls, 5)
    assert img.shape == (32, 32, 3)
    assert img.dtype == np.float32
    assert img.min() >= 0.0 and img.max() <= 1.0


def test_objects_differ_from_background():
    bg = scenes.make_crop(0, 9)
    for cls in range(1, scenes.NUM_CLASSES):
        obj = scenes.make_crop(cls, 9)
        assert (bg != obj).sum() > 50, f"class {cls} barely visible"


def test_primitives_match_mask_semantics():
    img = np.zeros((8, 8, 3), np.float32)
    scenes.fill_rect(img, 2, 2, 5, 4, (1.0, 0.0, 0.0))
    assert img[2, 2, 0] == 1.0 and img[3, 4, 0] == 1.0
    assert img[4, 4, 0] == 0.0  # y1 exclusive
    img2 = np.zeros((9, 9, 3), np.float32)
    scenes.fill_disk(img2, 4, 4, 2, (0.0, 1.0, 0.0))
    assert img2[4, 4, 1] == 1.0 and img2[4, 6, 1] == 1.0
    assert img2[6, 6, 1] == 0.0  # corner outside r


def test_ring_has_hole():
    img = np.zeros((16, 16, 3), np.float32)
    scenes.fill_ring(img, 8, 8, 5, 2, (1.0, 1.0, 1.0))
    assert img[8, 8].sum() == 0.0  # center empty
    assert img[8, 3].sum() > 0  # rim painted
