"""AOT lowering tests: HLO text artifacts are complete and loadable.

Full end-to-end numerics (rust loads + executes these artifacts) are
asserted by rust/tests/runtime_golden.rs; here we check the python side
of the contract: text form, no elided constants, manifest consistency.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, scenes


@pytest.fixture(scope="module")
def folded_eoc():
    p, s = model.init_eoc(seed=1)
    return model.fold_eoc(p, s)


def test_lower_model_emits_parsable_hlo(folded_eoc):
    text = aot.lower_model(model.eoc_infer, folded_eoc, batch=2)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # weights must be embedded, not elided
    assert "{...}" not in text
    # single-arg entry (the crop batch), tuple result
    assert "f32[2,32,32,3]" in text


def test_lower_framediff_has_right_shapes():
    text = aot.lower_framediff()
    assert "HloModule" in text
    assert f"f32[{aot.FRAME_H},{aot.FRAME_W}]" in text
    assert "{...}" not in text


def test_lower_fl_train_step_signature():
    text = aot.lower_fl()
    assert "HloModule" in text
    assert f"f32[{aot.FL_DIM},{aot.FL_CLASSES}]" in text
    assert f"s32[{aot.FL_BATCH}]" in text


def test_fl_train_step_learns_in_python():
    # the same function that gets lowered must reduce loss when iterated
    rng = np.random.default_rng(0)
    w = jnp.zeros((aot.FL_DIM, aot.FL_CLASSES))
    b = jnp.zeros((aot.FL_CLASSES,))
    x = rng.standard_normal((aot.FL_BATCH, aot.FL_DIM)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.int32)
    first = None
    for _ in range(20):
        w, b, loss = aot.fl_train_step(w, b, x, y, jnp.float32(0.5))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.5


def test_golden_scene_list_covers_classes():
    classes = {c for c, _ in aot.GOLDEN_SCENES}
    assert classes == set(range(scenes.NUM_CLASSES))


@pytest.mark.slow
def test_quick_build_roundtrip(tmp_path):
    """Full (quick-mode) build: trains tiny models, writes artifacts."""
    manifest = aot.build(str(tmp_path), quick=True, log=lambda m: None)
    assert (tmp_path / "manifest.json").exists()
    assert (tmp_path / "eoc_b1.hlo.txt").exists()
    assert (tmp_path / "golden" / "crops.bin").exists()
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk["quick"] is True
    assert on_disk["models"]["coc"]["outputs"] == scenes.NUM_CLASSES
    assert manifest["crop"] == 32
    # golden file sizes consistent with header
    raw = (tmp_path / "golden" / "crops.bin").read_bytes()
    import struct

    n, crop, ch = struct.unpack("<III", raw[:12])
    assert len(raw) == 12 + n * crop * crop * ch * 4
