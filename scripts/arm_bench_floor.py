#!/usr/bin/env python3
"""Arm the absolute bench floor from a fresh CI bench record.

Usage: arm_bench_floor.py RECORD.json FLOOR_SPEC.json OUT.json [DERATE]

The committed BENCH_FLOOR.json ships as a SPEC: it lists the gated
metric paths (mirroring benchkit::CHECKED_METRICS) but carries no
numbers, because the authoring container has no toolchain to measure
with. CI calls this script after the first healthy `ace bench --check`
run to derive the numbers instead of a human typing them in:

  floor[obj][key] = record[obj][key] * DERATE

DERATE (default 0.60) absorbs runner-class variance — the floor is an
absolute backstop under the 25%-tolerance rolling-median gate, not a
second tight gate. The armed record is kept in a sticky CI cache (so
later runs gate against the FIRST healthy run, not a ratcheting one)
and uploaded as an artifact for a maintainer to commit verbatim.

If FLOOR_SPEC already carries a number for any gated metric (i.e. a
maintainer committed an armed floor), it is copied through unchanged —
self-arming never overrides committed numbers.
"""

import json
import os
import sys


def main(argv):
    if len(argv) < 4:
        sys.exit(__doc__)
    record_path, spec_path, out_path = argv[1:4]
    derate = float(argv[4]) if len(argv) > 4 else 0.60

    with open(record_path) as f:
        record = json.load(f)
    with open(spec_path) as f:
        spec = json.load(f)

    paths = [tuple(p) for p in spec.get("checked_metrics", [])]
    if not paths:
        sys.exit(f"{spec_path}: no checked_metrics list — refusing to arm")

    def lookup(doc, obj, key):
        v = doc.get(obj)
        v = v.get(key) if isinstance(v, dict) else None
        return v if isinstance(v, (int, float)) and v > 0 else None

    committed = {(o, k): lookup(spec, o, k) for o, k in paths}
    if any(v is not None for v in committed.values()):
        print(f"floor already armed in {spec_path}; copying it through")
        with open(out_path, "w") as f:
            json.dump(spec, f, indent=2)
        return

    floor = {
        "record": "absolute bench floor",
        "status": "armed-from-ci-run",
        "source_run": os.environ.get("GITHUB_RUN_ID", "local"),
        "derate": derate,
        "checked_metrics": [list(p) for p in paths],
    }
    missing = []
    for obj, key in paths:
        v = lookup(record, obj, key)
        if v is None:
            missing.append(f"{obj}.{key}")
            continue
        floor.setdefault(obj, {})[key] = v * derate
        print(f"armed {obj}.{key}: {v:.0f} * {derate} = {v * derate:.0f}")
    if missing:
        print(f"WARNING: record had no number for: {', '.join(missing)}")

    with open(out_path, "w") as f:
        json.dump(floor, f, indent=2)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main(sys.argv)
