#!/usr/bin/env python3
"""Two-process federation smoke for `ace serve --federate`.

Usage: federation_smoke.py HUB_ADDR EDGE_ADDR

The edge server was started with `--federate HUB_ADDR`. This script is
an independent client implementation of the 4-byte-length-framed JSON
protocol (so the smoke is not the rust codec talking to itself). It:

  1. waits for the federation link to come up (the link's pull
     subscription appears in the hub's `stats`);
  2. publishes on the edge and asserts a hub subscriber receives the
     message with `origin` = the edge broker's name (the PUSH side);
  3. publishes on the hub and asserts an edge subscriber receives it
     with `origin` = the hub broker's name (the PULL side);
  4. sends both servers a `shutdown` op — the workflow then `wait`s on
     both PIDs to pin the clean-exit behavior.
"""

import base64
import json
import socket
import struct
import sys
import time


def connect(addr, deadline):
    host, port = addr.rsplit(":", 1)
    while True:
        try:
            s = socket.create_connection((host, int(port)), timeout=2.0)
            s.settimeout(10.0)
            return s
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.2)


def send(s, obj):
    body = json.dumps(obj).encode()
    s.sendall(struct.pack(">I", len(body)) + body)


def recv(s):
    hdr = b""
    while len(hdr) < 4:
        chunk = s.recv(4 - len(hdr))
        if not chunk:
            raise RuntimeError("connection closed")
        hdr += chunk
    (n,) = struct.unpack(">I", hdr)
    body = b""
    while len(body) < n:
        chunk = s.recv(n - len(body))
        if not chunk:
            raise RuntimeError("connection closed mid-frame")
        body += chunk
    return json.loads(body)


def rpc(s, obj, want):
    """Send a request; skip delivery pushes; return the typed reply."""
    send(s, obj)
    while True:
        v = recv(s)
        if v.get("type") == "message":
            continue
        if v.get("type") == "error":
            raise RuntimeError(f"server error: {v}")
        if v.get("type") != want:
            raise RuntimeError(f"expected {want}, got {v}")
        return v


def wait_message(s, topic):
    while True:
        v = recv(s)  # the socket timeout bounds the wait
        if v.get("type") == "message" and v.get("topic") == topic:
            return v


def main():
    hub_addr, edge_addr = sys.argv[1], sys.argv[2]
    deadline = time.monotonic() + 30.0
    hub = connect(hub_addr, deadline)
    edge = connect(edge_addr, deadline)

    # the federation link's pull subscription shows up in hub stats
    while True:
        st = rpc(hub, {"type": "stats", "requestId": "h0"}, "stats_ok")
        if st["stats"]["subscriptions"] >= 1:
            break
        if time.monotonic() > deadline:
            raise RuntimeError("federation link never subscribed on the hub")
        time.sleep(0.2)
    caps = st.get("capabilities", [])
    assert "federation" in caps and "origin-publish" in caps, caps
    print(f"link up: hub speaks v{st.get('v')} with capabilities {caps}")

    rpc(hub, {"type": "subscribe", "filter": "fed/#", "requestId": "h1"},
        "subscribe_ok")
    rpc(edge, {"type": "subscribe", "filter": "fed/#", "requestId": "e1"},
        "subscribe_ok")

    payload = base64.b64encode(b"over-the-bridge").decode()
    # edge -> hub: the PUSH side of the link
    rpc(edge, {"type": "publish", "topic": "fed/up", "payload": payload,
               "requestId": "e2"}, "publish_ok")
    m = wait_message(hub, "fed/up")
    assert base64.b64decode(m["payload"]) == b"over-the-bridge", m
    assert m.get("origin") == "edge", f"push lost its origin: {m}"
    # hub -> edge: the PULL side of the link
    rpc(hub, {"type": "publish", "topic": "fed/down", "payload": payload,
              "requestId": "h2"}, "publish_ok")
    m = wait_message(edge, "fed/down")
    assert base64.b64decode(m["payload"]) == b"over-the-bridge", m
    assert m.get("origin") == "hub", f"pull lost its origin: {m}"

    # edge first (tears down the link), then the hub
    rpc(edge, {"type": "shutdown", "requestId": "e9"}, "shutdown_ok")
    rpc(hub, {"type": "shutdown", "requestId": "h9"}, "shutdown_ok")
    print("federation smoke OK: both directions delivered, origins intact")


if __name__ == "__main__":
    main()
